"""Per-kernel validation: Pallas (interpret=True on CPU) vs the naive jnp
oracle (kernels.ref) vs the production jnp path (core.sparse_sinkhorn),
swept over shapes and dtypes per the assignment."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ell_from_dense, pad_k, precompute,
                        rebucket_for_vocab_shards)
from repro.core import sparse_sinkhorn as core_ss
from repro.kernels import ops, ref

# the whole module exercises the Pallas kernel path; CI runs it explicitly
# via `pytest -m kernel` (see .github/workflows/ci.yml)
pytestmark = pytest.mark.kernel


def _problem(v, w, n, vr, nnz_hi, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(v, w)).astype(dtype)
    sel = rng.choice(v, vr, replace=False).astype(np.int32)
    r_sel = (rng.random(vr).astype(dtype) + 0.1)
    r_sel /= r_sel.sum()
    c = np.zeros((v, n), dtype)
    for j in range(n):
        widx = rng.choice(v, rng.integers(2, nnz_hi), replace=False)
        c[widx, j] = rng.random(widx.size).astype(dtype)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    pre = precompute(jnp.asarray(sel), jnp.asarray(r_sel),
                     jnp.asarray(vecs), 1.0)
    u = jnp.asarray(rng.random((vr, n)).astype(dtype) + 0.5)
    return pre, ell, u, vecs, sel


SHAPES = [(64, 16, 16, 5, 9), (128, 32, 24, 8, 12), (256, 48, 40, 13, 20)]


@pytest.mark.parametrize("v,w,n,vr,nnz_hi", SHAPES)
def test_sddmm_spmm_type1_threeway(v, w, n, vr, nnz_hi):
    pre, ell, u, _, _ = _problem(v, w, n, vr, nnz_hi, seed=v)
    k_pad = pad_k(pre.K)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    x_ref = ref.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals)
    x_core = core_ss.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals)
    x_pal = ops.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals)
    np.testing.assert_allclose(np.asarray(x_core), np.asarray(x_ref),
                               rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(x_pal), np.asarray(x_ref),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("v,w,n,vr,nnz_hi", SHAPES)
def test_sddmm_spmm_type2_threeway(v, w, n, vr, nnz_hi):
    pre, ell, u, _, _ = _problem(v, w, n, vr, nnz_hi, seed=v + 1)
    k_pad, km_pad = pad_k(pre.K), pad_k(pre.KM)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    w_ref = ref.sddmm_spmm_type2(k_pad, km_pad, u, cols, vals)
    w_core = core_ss.sddmm_spmm_type2(k_pad, km_pad, u, cols, vals)
    w_pal = ops.sddmm_spmm_type2(k_pad, km_pad, u, cols, vals)
    np.testing.assert_allclose(np.asarray(w_core), np.asarray(w_ref),
                               rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(w_pal), np.asarray(w_ref),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("docs_blk", [4, 8, 16])
def test_kernel_docs_blk_invariance(docs_blk):
    """BlockSpec tiling must not change results."""
    pre, ell, u, _, _ = _problem(96, 16, 32, 7, 10, seed=7)
    k_pad = pad_k(pre.K)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    base = ops.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals, docs_blk=8)
    got = ops.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals,
                               docs_blk=docs_blk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)


@pytest.mark.parametrize("vr,v", [(3, 64), (11, 96), (17, 128)])
def test_kernel_unaligned_shapes(vr, v):
    """ops.py padding must handle non-multiple-of-8 v_r and odd doc counts."""
    pre, ell, u, _, _ = _problem(v, 16, 21, vr, 8, seed=vr * v)
    k_pad = pad_k(pre.K)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    x_ref = ref.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals)
    x_pal = ops.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals)
    np.testing.assert_allclose(np.asarray(x_pal), np.asarray(x_ref),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("n,m,w", [(8, 64, 16), (13, 96, 300), (32, 128, 64)])
def test_cdist_kernel(n, m, w):
    rng = np.random.default_rng(n * m)
    a = jnp.asarray(rng.normal(size=(n, w)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(m, w)).astype(np.float32))
    got = ops.cdist(a, b, v_tile=32)
    want = ref.cdist(a, b)
    # matmul expansion loses ~1e-3 absolute to cancellation (documented)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=5e-3)


def test_cdist_kernel_squared_exact_on_grid():
    """Squared distances on integer grids are exactly representable."""
    a = jnp.asarray(np.arange(8 * 4, dtype=np.float32).reshape(8, 4) % 5)
    b = jnp.asarray(np.arange(16 * 4, dtype=np.float32).reshape(16, 4) % 7)
    got = ops.cdist(a, b, v_tile=16, squared=True)
    want = ref.cdist(a, b, squared=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("n,m", [(8, 77), (13, 100)])
def test_cdist_pad_to_tile_arbitrary_v(n, m):
    """V not divisible by v_tile: the kernels pad the vocab axis internally
    and slice back (the old hard requirement V % v_tile == 0 is gone)."""
    rng = np.random.default_rng(n * m)
    a = jnp.asarray(rng.normal(size=(n, 24)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(m, 24)).astype(np.float32))
    got = ops.cdist(a, b, v_tile=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.cdist(a, b)),
                               rtol=2e-3, atol=5e-3)
    k, km = ops.cdist_kexp(a, b, lamb=1.0, v_tile=32)
    k_ref, km_ref = ref.cdist_kexp(a, b, lamb=1.0)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k_ref),
                               rtol=5e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(km), np.asarray(km_ref),
                               rtol=5e-3, atol=1e-3)


@pytest.mark.parametrize("m_rows,v", [(5, 80), (21, 77), (64, 96)])
def test_cdist_kexp_rows_matches_full(m_rows, v):
    """Row-subset fused kexp (the cache-miss path): rows of an arbitrary
    id subset == the same rows of the full-stripe kernel and the oracle,
    across non-tile-multiple row counts AND vocab sizes."""
    rng = np.random.default_rng(m_rows * v)
    vecs = jnp.asarray(rng.normal(size=(v, 24)).astype(np.float32))
    ids = jnp.asarray(rng.choice(v, m_rows, replace=False).astype(np.int32))
    k_rows, km_rows = ops.cdist_kexp_rows(vecs[ids], vecs, lamb=1.0,
                                          rows_blk=8, v_tile=32)
    assert k_rows.shape == (m_rows, v)
    k_ref, km_ref = ref.cdist_kexp(vecs[ids], vecs, lamb=1.0)
    np.testing.assert_allclose(np.asarray(k_rows), np.asarray(k_ref),
                               rtol=5e-3, atol=1e-4)
    # KM inherits the matmul-expansion cancellation of M (~1e-3 absolute,
    # documented at test_cdist_kernel)
    np.testing.assert_allclose(np.asarray(km_rows), np.asarray(km_ref),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("lamb", [0.5, 1.0, 4.0])
def test_cdist_kexp_fused(lamb):
    rng = np.random.default_rng(int(lamb * 10))
    a = jnp.asarray(rng.normal(size=(9, 24)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(80, 24)).astype(np.float32))
    k_got, km_got = ops.cdist_kexp(a, b, lamb=lamb, v_tile=16)
    k_ref, km_ref = ref.cdist_kexp(a, b, lamb=lamb)
    np.testing.assert_allclose(np.asarray(k_got), np.asarray(k_ref),
                               rtol=5e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(km_got), np.asarray(km_ref),
                               rtol=5e-3, atol=1e-3)


def test_chunked_driver_matches_monolithic():
    """Single-chip vocab-chunked kernel == unchunked (multi-chip layout)."""
    pre, ell, u, _, _ = _problem(128, 16, 24, 9, 10, seed=3)
    k_pad = pad_k(pre.K)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    x_full = core_ss.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals)
    shards = 4
    rb = rebucket_for_vocab_shards(ell, shards)
    vloc = 128 // shards
    k_chunks = jnp.stack([pad_k(pre.K[:, s * vloc:(s + 1) * vloc])
                          for s in range(shards)])
    x_chunk = ops.sddmm_spmm_chunked(k_chunks, pre.r, u,
                                     jnp.asarray(rb.cols),
                                     jnp.asarray(rb.vals))
    np.testing.assert_allclose(np.asarray(x_chunk), np.asarray(x_full),
                               rtol=1e-4, atol=1e-6)
