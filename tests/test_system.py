"""End-to-end behaviour tests: trainer with failure injection + restart,
multi-device distributed WMD (subprocess: needs forced device count), and
the serving loop."""
import os
import subprocess
import sys

import jax
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("REPRO_FAILED_ONCE", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_trainer_failure_restart_loss_decreases():
    code = """
import jax, tempfile
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.optim import adamw, warmup_cosine
from repro.data import TokenPipeline
from repro.train import Trainer
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_smoke_config("gemma-2b")
model = build_model(cfg, q_block=16, kv_block=16)
opt = adamw(warmup_cosine(3e-4, warmup_steps=3, total_steps=20))
pipe = TokenPipeline(cfg, batch=8, seq_len=32)
with tempfile.TemporaryDirectory() as td:
    tr = Trainer(model, opt, mesh, pipe, ckpt_dir=td, ckpt_every=4,
                 log_fn=lambda s: None)
    try:
        tr.run(jax.random.PRNGKey(0), 12, fail_at=6)
        raise SystemExit("expected failure not raised")
    except RuntimeError:
        pass
    tr2 = Trainer(model, opt, mesh, pipe, ckpt_dir=td, ckpt_every=4,
                  log_fn=lambda s: None)
    out = tr2.run(jax.random.PRNGKey(0), 12)
    h = out["history"]
    assert h[0]["step"] == 4, h[0]
    assert h[-1]["step"] == 11
    print("RESUMED_OK", h[0]["loss"], h[-1]["loss"])
"""
    stdout = _run_subprocess(code)
    assert "RESUMED_OK" in stdout
    parts = stdout.strip().split()
    assert float(parts[-1]) < float(parts[-2])  # loss decreased post-restart


def test_distributed_wmd_matches_single_chip():
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import (select_query, sinkhorn_wmd_sparse, ell_from_dense,
                        rebucket_for_vocab_shards)
from repro.core.distributed import build_wmd_fn, shard_wmd_inputs, pad_query
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(2)
V, w, N, vrn = 256, 32, 64, 9
vecs = rng.normal(size=(V, w)).astype(np.float32)
r = np.zeros(V, np.float32); idx = rng.choice(V, vrn, replace=False)
r[idx] = rng.random(vrn).astype(np.float32); r /= r.sum()
c = np.zeros((V, N), np.float32)
for j in range(N):
    widx = rng.choice(V, rng.integers(3, 17), replace=False)
    c[widx, j] = rng.random(widx.size).astype(np.float32)
    c[:, j] /= c[:, j].sum()
sel_idx, r_sel = select_query(r)
ell = ell_from_dense(c)
ref = np.asarray(sinkhorn_wmd_sparse(sel_idx, r_sel, jnp.asarray(ell.cols),
                                     jnp.asarray(ell.vals), vecs, 1.0, 12))
sel_p, r_p, mask = pad_query(sel_idx, r_sel, 16)
rb = rebucket_for_vocab_shards(ell, 2)
fn = build_wmd_fn(mesh, lamb=1.0, max_iter=12)
vd, cd, vld = shard_wmd_inputs(mesh, vecs, rb.cols, rb.vals)
got = np.asarray(fn(jnp.asarray(vecs[sel_p]), jnp.asarray(r_p),
                    jnp.asarray(mask), vd, cd, vld))
err = np.abs(got - ref).max() / np.abs(ref).max()
assert err < 1e-4, err
print("DIST_WMD_OK", err)
"""
    stdout = _run_subprocess(code)
    assert "DIST_WMD_OK" in stdout


def test_wmd_service_end_to_end():
    """Single-device service: corpus load, query, top-k retrieval sanity."""
    from repro.configs import sinkhorn_wmd as wmd_cfg
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = wmd_cfg.smoke_config()
    data = make_corpus(vocab_size=cfg.vocab_size, embed_dim=cfg.embed_dim,
                       num_docs=cfg.num_docs, num_queries=2,
                       query_words=cfg.v_r - 2, seed=0)
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell)
    d = svc.query(data.queries[0])
    assert d.shape == (cfg.num_docs,)
    assert np.isfinite(d).all() and (d > 0).all()
    idx, dist = svc.top_k(data.queries[0], k=5)
    assert np.all(np.diff(dist) >= 0)
    batch = svc.query_batch(data.queries)
    assert batch.shape == (2, cfg.num_docs)


def test_serve_decode_loop_runs():
    """LM serving loop produces tokens without NaN logits."""
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.serving import build_serve_fns
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_smoke_config("starcoder2-3b")
    model = build_model(cfg, q_block=8, kv_block=8)
    jit_prefill, jit_decode = build_serve_fns(model, mesh, max_len=48)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": np.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                  np.int32)}
    with mesh:
        logits, cache = jit_prefill(2)(params, batch)
        dec = jit_decode(2)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        for _ in range(8):
            logits, cache = dec(params, cache, tok)
            assert bool(jnp.isfinite(logits).all())
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
