"""LiveCorpus unit tests: upserts, tombstones, recovery, compaction, and
the `core.formats` mutation edges the live path leans on. Pure numpy --
no jax service here (the service-level contract lives in the golden table
and the ingest chaos suite)."""
import os

import numpy as np
import pytest

from repro.core import formats
from repro.data.live_corpus import LiveCorpus

V = 32


def _doc(rng, nnz=4):
    wids = rng.choice(V, size=nnz, replace=False)
    cnts = rng.integers(1, 10, size=nnz)
    return [(int(w), float(c)) for w, c in zip(wids, cnts)]


def _oneshot_ell(lc):
    """The reference: one-shot build of the live doc set, ascending id."""
    return formats.ell_from_doc_lists(
        [d for _, d in lc.live_docs()], V,
        nnz_align=lc.nnz_align, normalize=lc.normalize)


def _live_rows(lc):
    """(cols, vals) per live doc ascending -- what the result gather sees."""
    ids, seg, row = lc.locations()
    base, delta = lc.base_ell, lc.delta_ell
    out = []
    for s, r in zip(seg, row):
        e = base if s == 0 else delta
        out.append((e.cols[r], e.vals[r]))
    return out


def assert_rows_match_oneshot(lc):
    """Every live row holds exactly the slots a one-shot build would,
    bitwise (modulo trailing padding, which is inert by construction)."""
    ref = _oneshot_ell(lc)
    rows = _live_rows(lc)
    assert len(rows) == ref.num_docs
    for j, (cols, vals) in enumerate(rows):
        live = ref.vals[j] != 0.0
        got_live = vals != 0.0
        np.testing.assert_array_equal(cols[got_live], ref.cols[j][live])
        np.testing.assert_array_equal(vals[got_live], ref.vals[j][live])
        assert (cols[~got_live] == V).all()     # dead slots are padding


def test_empty_corpus(tmp_path):
    lc = LiveCorpus(str(tmp_path), V)
    assert lc.num_live == 0
    assert lc.base_ell.num_docs >= 0
    assert lc.live_ids().size == 0
    assert lc.stats()["gen"] == 0


def test_add_remove_upsert(tmp_path):
    rng = np.random.default_rng(0)
    lc = LiveCorpus(str(tmp_path), V)
    docs = {i: _doc(rng) for i in range(6)}
    assert lc.add_docs(list(docs), list(docs.values())) == 6
    assert lc.num_live == 6
    assert_rows_match_oneshot(lc)

    assert lc.remove_docs([2, 4]) == 2
    assert lc.num_live == 4
    assert set(lc.live_ids().tolist()) == {0, 1, 3, 5}
    assert_rows_match_oneshot(lc)

    new3 = _doc(rng, nnz=2)
    lc.add_docs([3], [new3])                       # upsert replaces
    assert lc.num_live == 4
    assert dict(lc.live_docs())[3] == new3
    assert_rows_match_oneshot(lc)


def test_remove_never_added_id_is_noop(tmp_path):
    lc = LiveCorpus(str(tmp_path), V)
    lc.add_docs([1], [[(0, 1.0)]])
    assert lc.remove_docs([99]) == 0               # never added
    assert lc.remove_docs([1]) == 1
    assert lc.remove_docs([1]) == 0                # already gone
    assert lc.num_live == 0
    lc.close()
    # the no-ops were logged; replay applies them as no-ops again
    lc2 = LiveCorpus(str(tmp_path), V)
    assert lc2.num_live == 0


def test_empty_doc_upsert(tmp_path):
    lc = LiveCorpus(str(tmp_path), V)
    lc.add_docs([0, 1], [[(3, 2.0)], []])          # empty doc is legal
    assert lc.num_live == 2
    np.testing.assert_array_equal(lc.live_empty_mask(), [False, True])
    lc.add_docs([0], [[]])                         # upsert TO empty
    np.testing.assert_array_equal(lc.live_empty_mask(), [True, True])
    assert_rows_match_oneshot(lc)
    lc.close()
    lc2 = LiveCorpus(str(tmp_path), V)             # survives recovery
    np.testing.assert_array_equal(lc2.live_empty_mask(), [True, True])


def test_duplicate_word_ids_within_doc(tmp_path):
    # duplicates occupy separate slots, exactly as ell_from_doc_lists
    # stores them (the engine sums slot contributions)
    doc = [(5, 1.0), (5, 2.0), (7, 1.0)]
    lc = LiveCorpus(str(tmp_path), V)
    lc.add_docs([0], [doc])
    assert_rows_match_oneshot(lc)
    (cols, vals), = _live_rows(lc)
    assert cols[:3].tolist() == [5, 5, 7]
    np.testing.assert_allclose(vals[:3], [0.25, 0.5, 0.25])


def test_validation_rejects_before_wal(tmp_path):
    lc = LiveCorpus(str(tmp_path), V)
    with pytest.raises(ValueError):
        lc.add_docs([0], [[(V, 1.0)]])             # word id out of vocab
    with pytest.raises(ValueError):
        lc.add_docs([0], [[(1, -1.0)]])            # negative count
    with pytest.raises(ValueError):
        lc.add_docs([0], [[(1, float("nan"))]])    # non-finite
    with pytest.raises(ValueError):
        lc.add_docs([0, 1], [[]])                  # len mismatch
    assert lc.num_live == 0
    assert lc.stats()["wal_bytes"] == 0            # nothing was logged
    lc.close()
    assert LiveCorpus(str(tmp_path), V).num_live == 0


def test_recovery_replays_wal(tmp_path):
    rng = np.random.default_rng(1)
    lc = LiveCorpus(str(tmp_path), V)
    docs = {i: _doc(rng) for i in range(8)}
    lc.add_docs(list(docs), list(docs.values()))
    lc.remove_docs([0, 3])
    lc.add_docs([1], [_doc(rng)])                  # upsert
    want = lc.live_docs()
    lc.close()

    lc2 = LiveCorpus(str(tmp_path), V)             # no snapshot yet: replay
    assert lc2.live_docs() == want
    assert_rows_match_oneshot(lc2)


def test_compaction_and_gc(tmp_path):
    rng = np.random.default_rng(2)
    lc = LiveCorpus(str(tmp_path), V)
    docs = {i: _doc(rng) for i in range(5)}
    lc.add_docs(list(docs), list(docs.values()))
    lc.remove_docs([2])
    want = lc.live_docs()
    v_before = lc.base_version
    lc.compact()
    assert lc.gen == 1
    assert lc.base_version > v_before
    assert lc.stats()["delta_rows"] == 0           # delta merged into base
    assert lc.live_docs() == want
    assert_rows_match_oneshot(lc)
    names = os.listdir(str(tmp_path))
    assert "snapshot_00000001" in names
    assert not any(n.endswith(".tmp") for n in names)
    assert "wal_00000000.log" not in names         # old generation gc'd

    lc.add_docs([9], [_doc(rng)])                  # keep mutating after
    want = lc.live_docs()
    lc.close()
    lc2 = LiveCorpus(str(tmp_path), V)             # snapshot + replay
    assert lc2.gen == 1
    assert lc2.live_docs() == want
    assert_rows_match_oneshot(lc2)


def test_compaction_of_empty_corpus(tmp_path):
    lc = LiveCorpus(str(tmp_path), V)
    lc.add_docs([0], [[(1, 1.0)]])
    lc.remove_docs([0])
    lc.compact()                                   # empty corpus snapshot
    assert lc.num_live == 0
    lc.close()
    assert LiveCorpus(str(tmp_path), V).num_live == 0


def test_delta_growth_rows_and_width(tmp_path):
    rng = np.random.default_rng(3)
    lc = LiveCorpus(str(tmp_path), V, min_capacity=2, nnz_align=4)
    for i in range(9):                             # forces two row doublings
        lc.add_docs([i], [_doc(rng, nnz=2)])
    assert lc.stats()["delta_capacity"] >= 9
    lc.add_docs([100], [_doc(rng, nnz=7)])         # forces nnz widening
    assert lc.stats()["delta_nnz_max"] >= 8        # rounded to align
    assert_rows_match_oneshot(lc)


def test_bucket_by_length_with_empty_delta(tmp_path):
    # the service's refresh rebuckets the delta even when it is empty
    # (all-pad capacity rows); length-0 rows go to NO bucket (they scatter
    # back as exact zeros) and the vocab-shard rebucket stays all-pad
    lc = LiveCorpus(str(tmp_path), V)
    lc.add_docs([0, 1], [[(1, 1.0)], [(2, 1.0), (3, 1.0)]])
    lc.compact()                                   # delta is now empty
    delta = lc.delta_ell
    assert (delta.vals == 0.0).all()
    rb = formats.bucket_by_length(delta)
    assert rb.buckets == ()                        # stable: no phantom docs
    rbs = formats.rebucket_for_vocab_shards(delta, 2)
    assert (rbs.vals == 0.0).all()                 # all-pad in every shard
    assert (rbs.cols == rbs.num_vocab).all()

    # mixed case: live delta rows bucket, capacity pad rows are dropped,
    # and scatter reassembles corpus order with zeros in the dropped slots
    lc.add_docs([7], [[(4, 1.0)]])
    delta = lc.delta_ell
    rb = formats.bucket_by_length(delta)
    assert sum(b.num_docs for b in rb.buckets) == 1
    out = rb.scatter([np.full(b.num_docs, 9.0) for b in rb.buckets],
                     delta.num_docs)
    assert out[np.concatenate(rb.doc_ids)].tolist() == [9.0]
    assert (np.delete(out, np.concatenate(rb.doc_ids)) == 0.0).all()


def test_vocab_mismatch_rejected_on_open(tmp_path):
    lc = LiveCorpus(str(tmp_path), V)
    lc.add_docs([0], [[(1, 1.0)]])
    lc.compact()
    lc.close()
    with pytest.raises(ValueError, match="vocab"):
        LiveCorpus(str(tmp_path), V * 2)


def test_snapshot_checksum_detects_corruption(tmp_path):
    lc = LiveCorpus(str(tmp_path), V)
    lc.add_docs([0], [[(1, 1.0)]])
    lc.compact()
    lc.close()
    blob_path = os.path.join(str(tmp_path), "snapshot_00000001",
                             "docs.msgpack")
    with open(blob_path, "r+b") as f:
        b = f.read(1)
        f.seek(0)
        f.write(bytes([b[0] ^ 0x01]))
    with pytest.raises(RuntimeError, match="checksum"):
        LiveCorpus(str(tmp_path), V)


def test_normalize_false_preserves_weights(tmp_path):
    lc = LiveCorpus(str(tmp_path), V, normalize=False)
    lc.add_docs([0], [[(1, 0.25), (2, 0.75)]])
    (cols, vals), = _live_rows(lc)
    np.testing.assert_array_equal(vals[:2], np.float32([0.25, 0.75]))
    assert_rows_match_oneshot(lc)


# ---------------------------------------------------------------------------
# compaction concurrency: the corpus lock is held only for the swap
# ---------------------------------------------------------------------------

def test_concurrent_ops_during_compaction(tmp_path):
    """Reads AND writes proceed while a compaction is mid-build (its lock
    is released across the rebuild + snapshot write), and the final state
    equals a one-shot build of the full logical doc set -- the writes that
    landed in the build window survive the segment swap."""
    import threading

    rng = np.random.default_rng(0)
    built = threading.Event()
    resume = threading.Event()

    def hook(name):
        if name == "compact.built":
            built.set()           # compaction is now OUTSIDE the lock,
            resume.wait(5.0)      # parked mid-build until we say go

    lc = LiveCorpus(str(tmp_path), V, crash_hook=hook)
    for i in range(6):
        lc.add_docs([i], [_doc(rng)])

    t = threading.Thread(target=lc.compact)
    t.start()
    assert built.wait(5.0)
    # corpus lock is free: these must NOT deadlock behind the compaction
    assert lc.num_live == 6
    ids_mid = lc.live_ids()
    assert ids_mid.size == 6
    lc.add_docs([100], [_doc(rng)])            # write during the build
    lc.remove_docs([0])
    assert lc.stats()["compacting"] is True
    resume.set()
    t.join(10.0)
    assert not t.is_alive()

    # build-window writes survived the swap (snapshot was pre-write S0)
    assert sorted(i for i, _ in lc.live_docs()) == [1, 2, 3, 4, 5, 100]
    assert_rows_match_oneshot(lc)
    assert lc.stats()["compacting"] is False
    lc.close()

    # ... and survive recovery: the snapshot lacks them, the new
    # generation's WAL (re-logged at swap) has them
    rec = LiveCorpus(str(tmp_path), V)
    assert sorted(i for i, _ in rec.live_docs()) == [1, 2, 3, 4, 5, 100]
    assert_rows_match_oneshot(rec)
    rec.close()


def test_compaction_lock_hold_histogram(tmp_path):
    """With a metrics registry wired, each compaction records its two
    short locked phases -- the observable guard against regressing back
    to holding the corpus lock across the whole rebuild."""
    from repro.obs.metrics import MetricsRegistry

    rng = np.random.default_rng(1)
    reg = MetricsRegistry()
    lc = LiveCorpus(str(tmp_path), V)
    lc.metrics = reg
    lc.add_docs(list(range(5)), [_doc(rng) for _ in range(5)])
    lc.compact()
    h = reg.histogram("wmd_compact_lock_hold_seconds")
    assert h.count == 2                        # begin-capture + swap
    lc.compact()
    assert h.count == 4
    lc.close()


def test_recovery_replays_all_wal_generations(tmp_path):
    """A crash after the snapshot rename but before the pending re-log
    leaves an acked record only in the OLD generation's WAL; recovery
    replays every surviving log ascending, so the ack is honored."""
    rng = np.random.default_rng(2)
    lc = LiveCorpus(str(tmp_path), V)
    lc.add_docs([0, 1], [_doc(rng), _doc(rng)])
    lc.close()

    # forge the crash window on disk: snapshot_1 exists (holding only doc
    # 0 -- the capture), wal_0 still holds both acked adds, wal_1 absent
    lc = LiveCorpus(str(tmp_path), V)
    lc._write_snapshot(1, [0], [lc._docs[0]])
    lc.close()

    rec = LiveCorpus(str(tmp_path), V)
    assert rec.stats()["gen"] == 1
    assert sorted(i for i, _ in rec.live_docs()) == [0, 1]   # ack honored
    assert_rows_match_oneshot(rec)
    rec.close()
