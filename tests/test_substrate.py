"""Substrate tests: AdamW, gradient compression, checkpoint roundtrip,
fault-tolerance monitor, elastic remesh, data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.distributed.fault_tolerance import (FaultPolicy, HeartbeatMonitor)
from repro.optim import (adamw, compress_grads, constant,
                         init_compression_state, warmup_cosine)


def test_adamw_minimizes_quadratic():
    opt = adamw(constant(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw w^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, warmup_steps=10, total_steps=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(jnp.asarray(55))) < 1e-3


def test_grad_compression_error_feedback():
    """int8 round-trip with error feedback: the *accumulated* compressed
    signal converges to the true signal (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(1000,)) * 1e-3,
                               jnp.float32)}
    state = init_compression_state(g_true)
    acc_comp = np.zeros(1000)
    for _ in range(20):
        g_comp, state = compress_grads(g_true, state)
        acc_comp += np.asarray(g_comp["w"])
    acc_true = 20 * np.asarray(g_true["w"])
    # error feedback keeps accumulated error ~1 quantization step, not 20
    err = np.abs(acc_comp - acc_true).max()
    one_step_q = float(np.abs(np.asarray(g_true["w"])).max()) / 127
    assert err < 3 * one_step_q


def test_checkpoint_roundtrip():
    state = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
             "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)},
             "scalar": jnp.asarray(7, jnp.int32)}
    with tempfile.TemporaryDirectory() as td:
        ckpt.save(td, 5, state, mesh_signature="data=1")
        assert ckpt.latest_step(td) == 5
        like = jax.eval_shape(lambda: state)
        restored = ckpt.restore(td, 5, like)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch_rejected():
    state = {"a": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as td:
        ckpt.save(td, 1, state)
        wrong = jax.eval_shape(lambda: {"b": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="tree does not match"):
            ckpt.restore(td, 1, wrong)


def test_checkpoint_gc_keeps_latest():
    state = {"a": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as td:
        c = ckpt.AsyncCheckpointer(td, keep=2)
        for step in (1, 2, 3, 4):
            c.save(step, state)
        c.wait()
        steps = sorted(d for d in os.listdir(td) if d.startswith("step_"))
        assert len(steps) == 2
        assert ckpt.latest_step(td) == 4


def test_heartbeat_monitor_detects_death_and_stragglers():
    clock = {"t": 0.0}
    mon = HeartbeatMonitor(4, FaultPolicy(timeout_s=10, straggler_factor=2,
                                          straggler_strikes=2),
                           clock=lambda: clock["t"])
    mon.set_median_step(1.0)
    for t in range(5):
        clock["t"] = float(t)
        for h in range(4):
            if h == 3 and t >= 2:
                continue                       # host 3 goes silent at t=2
            slow = 5.0 if h == 2 else 1.0      # host 2 is a straggler
            mon.heartbeat(h, t, step_seconds=slow)
    clock["t"] = 12.0   # hosts 0-2 last seen t=4 (8s ago, alive);
    # host 3 last seen t=1 (11s ago > timeout, dead)
    assert mon.dead_hosts() == [3]
    assert mon.respawn_candidates() == [2]
    assert mon.surviving() == 3


def test_elastic_remesh_factorings():
    from repro.distributed.elastic import remesh
    m = remesh(1, model_parallelism=16)
    assert m.devices.size == 1                 # degenerate single-device
    # named axes always present
    assert set(m.axis_names) <= {"pod", "data", "model"}


def test_data_pipeline_deterministic_and_restart_safe():
    from repro.configs import get_smoke_config
    from repro.data import TokenPipeline
    cfg = get_smoke_config("olmo-1b")
    p1 = TokenPipeline(cfg, batch=4, seq_len=16, seed=7)
    p2 = TokenPipeline(cfg, batch=4, seq_len=16, seed=7)
    b1 = p1.batch_at(123)
    b2 = p2.batch_at(123)                      # fresh instance, same step
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p1.batch_at(124)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_wmd_corpus_statistics():
    """The synthetic corpus must reproduce the paper's density regime."""
    from repro.data import make_corpus
    data = make_corpus(vocab_size=5000, embed_dim=32, num_docs=200,
                       num_queries=2, seed=1)
    density = data.nnz / (5000 * 200)
    assert 1e-4 < density < 5e-2
    assert data.ell.pad_waste < 0.9
    # normalized doc histograms
    sums = data.ell.vals.sum(axis=1)
    live = sums > 0
    np.testing.assert_allclose(sums[live], 1.0, rtol=1e-5)
