"""Ingest chaos suite: kill the writer at every WAL / snapshot /
compaction boundary and assert crash-consistent recovery.

The core contract, asserted bitwise: a corpus assembled incrementally --
including crashes at seeded points and at *every enumerated* boundary --
recovers to answer queries bit-for-bit identical to the same logical doc
set built in one shot. And durability is one-directional: **no crash
point loses an acknowledged write** (un-acked writes may surface or not;
either is legal).

Runs as its own CI step (seeded, under pytest-timeout), not in tier-1 --
the boundary sweep re-runs recovery a few dozen times.
"""
import functools

import numpy as np
import pytest

from repro.core import formats
from repro.data.live_corpus import LiveCorpus
from repro.serving.faultinject import CrashInjector, InjectedCrash

V = 96


def _mk_doc(rng, nnz=None):
    nnz = int(rng.integers(2, 8)) if nnz is None else nnz
    wids = rng.choice(V, size=nnz, replace=False)
    cnts = rng.integers(1, 9, size=nnz)
    return [(int(w), float(c)) for w, c in zip(wids, cnts)]


def _ops(seed, n=14):
    """A deterministic mixed op sequence: adds, upserts, removes (of live
    and never-added ids), an empty-doc upsert, and two compactions."""
    rng = np.random.default_rng(seed)
    ops, live = [], set()
    for i in range(n):
        ops.append(("add", [i], [_mk_doc(rng)]))
        live.add(i)
        if i == 3:
            ops.append(("add", [1], [_mk_doc(rng)]))        # upsert
        if i == 5:
            ops.append(("remove", [2, 999]))                # live + never
            live.discard(2)
            ops.append(("add", [4], [[]]))                  # empty-doc upsert
        if i in (6, 10):
            ops.append(("compact",))
    ops.append(("remove", [0]))
    return ops


def _apply(lc, op):
    if op[0] == "add":
        return lc.add_docs(op[1], op[2])
    if op[0] == "remove":
        return lc.remove_docs(op[1])
    lc.compact()
    return None


def _reference_docs(seed):
    """The crash-free run's final doc set -- the bitwise target."""
    with _fresh(None, seed, "ref") as lc:
        for op in _ops(seed):
            _apply(lc, op)
        return lc.live_docs()


class _fresh:
    """Context manager yielding a LiveCorpus in a throwaway subdir."""

    def __init__(self, tmp_path, seed, tag, hook=None):
        import tempfile
        self.dir = tempfile.mkdtemp(prefix=f"chaos-{tag}-") \
            if tmp_path is None else str(tmp_path / f"{tag}")
        self.hook = hook

    def __enter__(self):
        self.lc = LiveCorpus(self.dir, V, crash_hook=self.hook)
        return self.lc

    def __exit__(self, *exc):
        try:
            self.lc.close()
        except Exception:
            pass
        return False


@functools.lru_cache(maxsize=4)
def _boundaries(seed) -> int:
    """Dry-run the op sequence with a counting hook to enumerate its
    crash boundaries (target mode with no target = pure counter)."""
    hook = CrashInjector()
    with _fresh(None, seed, "dryrun", hook=hook) as lc:
        for op in _ops(seed):
            _apply(lc, op)
    return hook.count


def test_boundary_count_is_stable():
    # the sweep's coverage claim rests on this enumeration being
    # deterministic and spanning both WAL and compaction boundary kinds
    n = _boundaries(7)
    assert n == _boundaries(7)
    hook = CrashInjector()
    with _fresh(None, 7, "kinds", hook=hook) as lc:
        for op in _ops(7):
            _apply(lc, op)
    kinds = set(hook.log)
    assert {"wal.append.pre", "wal.append.torn", "wal.append.synced",
            "compact.begin", "compact.built", "compact.snapshot.tmp",
            "compact.renamed", "compact.done"} <= kinds


@pytest.mark.parametrize("seed", [7])
def test_crash_sweep_every_boundary(tmp_path, seed):
    """Kill at boundary i for EVERY i; recover; finish; compare bitwise."""
    ops = _ops(seed)
    want = _reference_docs(seed)
    n_boundaries = _boundaries(seed)
    assert n_boundaries > 30            # sanity: the sweep is non-trivial

    for target in range(n_boundaries):
        hook = CrashInjector(target=target)
        d = str(tmp_path / f"sweep{target}")
        lc = LiveCorpus(d, V, crash_hook=hook)
        acked = []                      # ops whose call RETURNED pre-crash
        crashed_at = None
        for i, op in enumerate(ops):
            try:
                _apply(lc, op)
                acked.append(op)
            except InjectedCrash:
                crashed_at = i
                break
        assert crashed_at is not None, \
            f"target {target} never fired ({hook.count} boundaries crossed)"
        # simulate the kill: drop the instance, recover from disk only
        del lc
        rec = LiveCorpus(d, V)

        # durability: every acked op's effect is visible after recovery
        expect = {}
        for op in acked:
            if op[0] == "add":
                for i_, d_ in zip(op[1], op[2]):
                    expect[i_] = [(int(w), float(c)) for w, c in d_]
            elif op[0] == "remove":
                for i_ in op[1]:
                    expect.pop(i_, None)
        got = dict(rec.live_docs())
        # ids the crashed (un-acked) op touches may legally hold either
        # the pre-op or post-op value -- its fsync may or may not have
        # landed before the kill; every OTHER acked id must be intact
        crashed_op = ops[crashed_at]
        in_flight = set(crashed_op[1]) \
            if crashed_op[0] in ("add", "remove") else set()
        for i_, doc in expect.items():
            if i_ in in_flight:
                continue
            assert got.get(i_) == doc, \
                (f"boundary {target} ({hook.crashed_at}): acked doc {i_} "
                 f"lost or wrong after recovery")
        # ... and any EXTRA ids must come from the crashed op, nothing else
        extra = set(got) - set(expect)
        assert extra <= in_flight, \
            f"boundary {target}: phantom docs {extra - in_flight}"

        # finish the run: re-apply the crashed op (idempotent upsert /
        # remove / compact retry) and the rest, then compare bitwise
        for op in ops[crashed_at:]:
            _apply(rec, op)
        assert rec.live_docs() == want, f"boundary {target} diverged"
        rec.close()


@pytest.mark.parametrize("seed", range(4))
def test_seeded_multi_crash_interleavings(tmp_path, seed):
    """Seeded random kills (possibly several per run): recover after each
    and keep going; the survivors' final state is bitwise the reference."""
    ops = _ops(11)
    want = _reference_docs(11)
    d = str(tmp_path / f"seeded{seed}")
    hook = CrashInjector(seed=seed, p_crash=0.04)
    lc = LiveCorpus(d, V, crash_hook=hook)
    i, crashes = 0, 0
    while i < len(ops):
        try:
            _apply(lc, ops[i])
            i += 1
        except InjectedCrash:
            crashes += 1
            assert crashes < 100        # p=0.04 cannot livelock the run
            del lc
            lc = LiveCorpus(d, V, crash_hook=hook)  # hook keeps counting
    assert lc.live_docs() == want, \
        f"seed {seed} diverged after {crashes} crashes"
    lc.close()


def test_torn_wal_tail_recovers(tmp_path):
    """A crash mid-record (the torn boundary) leaves a half-written tail;
    recovery truncates it and the corpus reopens to the acked prefix."""
    d = str(tmp_path / "torn")
    hook = CrashInjector(target=7)      # 3 boundaries/append: crash is the
    lc = LiveCorpus(d, V, crash_hook=hook)   # torn boundary of append #3
    lc.add_docs([0], [[(1, 2.0)]])
    lc.add_docs([1], [[(2, 1.0), (3, 1.0)]])
    with pytest.raises(InjectedCrash):
        lc.add_docs([2], [[(4, 1.0)]])
    assert hook.crashed_at[1] == "wal.append.torn"
    rec = LiveCorpus(d, V)
    assert sorted(rec.live_ids().tolist()) == [0, 1]
    rec.add_docs([2], [[(4, 1.0)]])     # and the log extends cleanly
    assert sorted(rec.live_ids().tolist()) == [0, 1, 2]


# -- service level: the incremental == batch bitwise contract -------------

LAMB, MAX_ITER, TOP_K = 1.0, 8, 4


@functools.lru_cache(maxsize=1)
def _problem():
    rng = np.random.default_rng(1234)
    vecs = rng.normal(size=(V, 8)).astype(np.float32)
    docs = {i: _mk_doc(rng) for i in range(12)}
    rs = []
    for i in range(3):
        r = np.zeros(V, np.float32)
        idx = rng.choice(V, 5 + 2 * i, replace=False)
        r[idx] = rng.random(idx.size).astype(np.float32) + 0.1
        r /= r.sum()
        rs.append(r)
    return vecs, docs, rs


def _mk_service(**kw):
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService
    mesh = make_mesh((1, 1), ("data", "model"))
    ell = kw.pop("ell", None)
    live = kw.pop("live", None)
    n = live.num_live if live is not None else ell.num_docs
    nnz = live.base_ell.nnz_max if live is not None else ell.nnz_max
    cfg = WMDConfig(name="chaos", vocab_size=V, embed_dim=8, num_docs=n,
                    nnz_max=nnz, v_r=12, lamb=LAMB, max_iter=MAX_ITER)
    return WMDService(mesh=mesh, cfg=cfg, vecs=_problem()[0], ell=ell,
                      live=live, cache_capacity=64, prune_chunk=8,
                      bound_docs_chunk=None, **kw)


def test_service_bitwise_after_crash_and_recovery(tmp_path):
    """The flagship assertion: shuffled adds + upserts + removes + a
    compaction + a crash + recovery + more adds answers query_batch /
    top_k / bounds BIT-FOR-BIT like a one-shot build of the same docs."""
    vecs, docs, rs = _problem()
    d = str(tmp_path / "svc")

    # build incrementally, in shuffled order, with a wrong doc upserted
    # over and a crash at a compaction boundary along the way
    order = list(docs)
    np.random.default_rng(5).shuffle(order)
    hook = CrashInjector(target=None)
    lc = LiveCorpus(d, V, crash_hook=hook)
    lc.add_docs([order[0]], [[(0, 1.0)]])          # wrong content first
    for i in order[:8]:
        lc.add_docs([i], [docs[i]])                # (order[0] corrected)
    lc.add_docs([99], [_mk_doc(np.random.default_rng(42))])
    hook.target = hook.count + 3                   # inside the compaction
    with pytest.raises(InjectedCrash):
        lc.compact()
    del lc
    rec = LiveCorpus(d, V)                         # recover from disk
    rec.remove_docs([99])
    for i in order[8:]:
        rec.add_docs([i], [docs[i]])
    rec.compact()                                  # a clean one this time
    assert dict(rec.live_docs()) == {
        i: [(int(w), float(c)) for w, c in docs[i]] for i in docs}

    live_svc = _mk_service(live=rec)
    ref_ell = formats.ell_from_doc_lists(
        [docs[i] for i in sorted(docs)], V)
    ref_svc = _mk_service(ell=ref_ell)

    d_live = live_svc.query_batch(rs)
    d_ref = ref_svc.query_batch(rs)
    np.testing.assert_array_equal(d_live, d_ref)

    idx_l, dd_l = live_svc.top_k_batch(rs, TOP_K, prune=False)
    idx_r, dd_r = ref_svc.top_k_batch(rs, TOP_K, prune=False)
    np.testing.assert_array_equal(dd_l, dd_r)
    np.testing.assert_array_equal(idx_l, idx_r)    # ids ARE doc ids here

    # pruned top-k on live runs the segment-aware cascade (bounds over
    # the base, delta solved whole) -- same answers, honest stats
    idx_p, dd_p = live_svc.top_k_batch(rs, TOP_K, prune=True)
    np.testing.assert_array_equal(dd_p, dd_r)
    np.testing.assert_array_equal(idx_p, idx_r)
    assert live_svc.last_prune_stats["rerank"] == "live_pruned"

    # only the union rerank still degrades to the counted full scan
    idx_u, dd_u = live_svc.top_k_batch(rs, TOP_K, prune=True,
                                       rerank="union")
    np.testing.assert_array_equal(dd_u, dd_r)
    np.testing.assert_array_equal(idx_u, idx_r)
    assert live_svc.last_prune_stats["rerank"] == "live_full_scan"
    assert live_svc.metrics.counter("wmd_prune_fallback_total").value == 1

    lb_l = live_svc.query_batch_bounds(rs)
    lb_r = ref_svc.query_batch_bounds(rs)
    np.testing.assert_array_equal(lb_l, lb_r)

    # mutate again through the SERVICE api and re-check a route
    new_doc = _mk_doc(np.random.default_rng(77))
    live_svc.add_docs([50], [new_doc])
    ref2 = formats.ell_from_doc_lists(
        [docs[i] for i in sorted(docs)] + [new_doc], V)
    np.testing.assert_array_equal(
        live_svc.query_batch(rs),
        _mk_service(ell=ref2).query_batch(rs))
    rec.close()


def test_kcache_survives_corpus_mutation(tmp_path):
    """K-cache rows are functions of (word_id, lambda, vecs) only --
    corpus mutation must invalidate NOTHING. Embedding-row invalidation
    is the separately scoped hook."""
    vecs, docs, rs = _problem()
    lc = LiveCorpus(str(tmp_path / "kc"), V)
    lc.add_docs(list(docs), [docs[i] for i in sorted(docs)])
    svc = _mk_service(live=lc)
    svc.query_batch(rs)
    resident = svc.cache_resident
    assert resident > 0
    svc.add_docs([80], [[(3, 1.0)]])
    svc.remove_docs([0])
    svc.compact()
    assert svc.cache_resident == resident          # untouched by mutation
    svc.query_batch(rs)                            # still serves correctly
    dropped = svc.invalidate_embedding_rows(
        [int(np.flatnonzero(rs[0])[0])])
    assert dropped >= 0                            # scoped hook works
    lc.close()


def test_coalescer_writer_lane_chaos(tmp_path):
    """Reads and writes through the coalescer: merged write dispatches,
    per-request acks, read-your-writes FIFO, final corpus == one-shot."""
    from repro.serving import QueryCoalescer
    vecs, docs, rs = _problem()
    lc = LiveCorpus(str(tmp_path / "co"), V)
    base = {i: docs[i] for i in sorted(docs)}
    lc.add_docs(list(base), list(base.values()))
    svc = _mk_service(live=lc)

    with QueryCoalescer(svc, window_ms=4.0, max_batch=8) as co:
        futs = []
        for j in range(6):
            futs.append(("w", co.submit_add_docs(
                [100 + j], [_mk_doc(np.random.default_rng(j))])))
            futs.append(("r", co.submit(rs[j % len(rs)])))
        futs.append(("w", co.submit_remove_docs([100, 101])))
        futs.append(("r", co.submit(rs[0])))
        for kind, f in futs:
            res = f.result(timeout=60)
            if kind == "w":
                assert res >= 1                    # ack = ids durably logged
        st = co.stats()
        assert st.write_dispatches >= 2
        assert st.docs_added == 6 and st.docs_removed == 2

    # read-your-writes: the post-remove read sees the shrunken corpus
    assert sorted(svc.live_doc_ids.tolist()) == \
        sorted(list(base) + [102, 103, 104, 105])
    lc.close()
