"""Cache-blocked batched engine: batched Pallas kernels == batched jnp
fused (mixed-size padded queries, pad rows/slots inert), doc-chunked
iteration bitwise at the op level, early-exit convergence == fixed budget,
and the distributed convergence vote == single-host masking."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ell_from_dense, pad_k, precompute_batch, select_query,
                        sddmm_spmm_type1_batch, sddmm_spmm_type2_batch,
                        sinkhorn_wmd_converged_batch, sinkhorn_wmd_sparse_batch)
from repro.core.distributed import pad_query_batch
from repro.core.sparse_sinkhorn import safe_recip
from repro.kernels import ops, ref

LAMB, ITERS = 1.0, 12


@pytest.fixture(scope="module")
def batch_problem():
    """Corpus (non-dividing N = 45) + Q=4 mixed-v_r queries padded to 16."""
    rng = np.random.default_rng(11)
    v, w, n = 256, 24, 45
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        widx = rng.choice(v, rng.integers(4, 18), replace=False)
        c[widx, j] = rng.random(widx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    queries = []
    for vr in (4, 7, 11, 16):
        r = np.zeros(v, np.float32)
        idx = rng.choice(v, vr, replace=False)
        r[idx] = rng.random(vr).astype(np.float32)
        r /= r.sum()
        queries.append(r)
    sels, rsels = zip(*[select_query(r) for r in queries])
    sel_b, r_b, mask_b = pad_query_batch(sels, rsels, 16)
    pre = precompute_batch(jnp.asarray(sel_b), jnp.asarray(r_b),
                           jnp.asarray(vecs), LAMB,
                           row_mask=jnp.asarray(mask_b))
    return {"vecs": vecs, "ell": ell, "sels": sels, "rsels": rsels,
            "sel_b": sel_b, "r_b": r_b, "mask_b": mask_b, "pre": pre,
            "k_pad": pad_k(pre.K), "km_pad": pad_k(pre.KM),
            "cols": jnp.asarray(ell.cols), "vals": jnp.asarray(ell.vals),
            "u": safe_recip(jnp.full((4, 16, n), 1.0 / 16, jnp.float32))}


def _solver_args(p):
    return (jnp.asarray(p["sel_b"]), jnp.asarray(p["r_b"]), p["cols"],
            p["vals"], jnp.asarray(p["vecs"]), LAMB, ITERS)


# ---------------------------------------------------------------------------
# Batched kernel vs batched jnp fused (the acceptance gate)
# ---------------------------------------------------------------------------

@pytest.mark.kernel
def test_batched_kernel_type1_matches_jnp_fused(batch_problem):
    """ops.sddmm_spmm_type1_batch (interpret) == jnp fused == naive oracle
    on a mixed-size padded query bucket (pad rows present in K/r/u)."""
    p = batch_problem
    r_b = jnp.asarray(p["r_b"])
    x_jnp = sddmm_spmm_type1_batch(p["k_pad"], r_b, p["u"],
                                   p["cols"], p["vals"])
    x_ref = ref.sddmm_spmm_type1_batch(p["k_pad"], r_b, p["u"],
                                       p["cols"], p["vals"])
    for q_blk in (None, 2):  # single stripe covering Q, and 2-query stripes
        x_pal = ops.sddmm_spmm_type1_batch(p["k_pad"], r_b, p["u"],
                                           p["cols"], p["vals"], q_blk=q_blk)
        np.testing.assert_allclose(np.asarray(x_pal), np.asarray(x_jnp),
                                   rtol=2e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(x_pal), np.asarray(x_ref),
                                   rtol=2e-4, atol=1e-6)


@pytest.mark.kernel
def test_batched_kernel_type2_matches_jnp_fused(batch_problem):
    p = batch_problem
    w_jnp = sddmm_spmm_type2_batch(p["k_pad"], p["km_pad"], p["u"],
                                   p["cols"], p["vals"])
    w_ref = ref.sddmm_spmm_type2_batch(p["k_pad"], p["km_pad"], p["u"],
                                       p["cols"], p["vals"])
    w_pal = ops.sddmm_spmm_type2_batch(p["k_pad"], p["km_pad"], p["u"],
                                       p["cols"], p["vals"])
    np.testing.assert_allclose(np.asarray(w_pal), np.asarray(w_jnp),
                               rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(w_pal), np.asarray(w_ref),
                               rtol=2e-4, atol=1e-6)


@pytest.mark.kernel
def test_batched_kernel_pad_rows_and_slots_inert(batch_problem):
    """Pad-slot retargeting is bit-identical through the kernel (val == 0
    gates the accumulation), and an all-pad filler stripe solves to exactly
    zero through the full impl="kernel" batched solver."""
    p = batch_problem
    w_a = ops.sddmm_spmm_type2_batch(p["k_pad"], p["km_pad"], p["u"],
                                     p["cols"], p["vals"])
    cols_mut = jnp.where(p["vals"] == 0.0, 0, p["cols"])
    w_b = ops.sddmm_spmm_type2_batch(p["k_pad"], p["km_pad"], p["u"],
                                     cols_mut, p["vals"])
    np.testing.assert_array_equal(np.asarray(w_a), np.asarray(w_b))
    # all-pad filler query (the service's Q-bucket filler), kernel path
    wmd = sinkhorn_wmd_sparse_batch(
        jnp.zeros((1, 16), jnp.int32), jnp.ones((1, 16), jnp.float32),
        p["cols"], p["vals"], jnp.asarray(p["vecs"]), LAMB, ITERS,
        row_mask=jnp.zeros((1, 16), jnp.float32), impl="kernel")
    np.testing.assert_array_equal(np.asarray(wmd), 0.0)


@pytest.mark.kernel
def test_batched_solver_kernel_impl_matches_fused(batch_problem):
    """The full batched solver agrees across the impl table (the unified
    fused|unfused|kernel API of the tentpole)."""
    p = batch_problem
    kw = dict(row_mask=jnp.asarray(p["mask_b"]))
    base = np.asarray(sinkhorn_wmd_sparse_batch(*_solver_args(p), **kw))
    for impl in ("kernel", "unfused"):
        got = np.asarray(sinkhorn_wmd_sparse_batch(*_solver_args(p), **kw,
                                                   impl=impl))
        err = np.abs(got - base).max() / np.abs(base).max()
        assert err < 1e-4, (impl, err)


# ---------------------------------------------------------------------------
# Doc-chunked (cache-blocked) iteration
# ---------------------------------------------------------------------------

def test_chunked_op_bitwise_including_nondividing(batch_problem):
    """Chunked contraction == unchunked BITWISE at the op level (jitted),
    for dividing and non-dividing docs_chunk values (N = 45)."""
    p = batch_problem
    r_b = jnp.asarray(p["r_b"])
    t1 = jax.jit(functools.partial(sddmm_spmm_type1_batch),
                 static_argnames="docs_chunk")
    t2 = jax.jit(functools.partial(sddmm_spmm_type2_batch),
                 static_argnames="docs_chunk")
    x_base = np.asarray(t1(p["k_pad"], r_b, p["u"], p["cols"], p["vals"]))
    w_base = np.asarray(t2(p["k_pad"], p["km_pad"], p["u"],
                           p["cols"], p["vals"]))
    for dc in (0, 8, 15, 16, 32, 45):      # 0 = unchunked alias, no crash
        x_c = np.asarray(t1(p["k_pad"], r_b, p["u"], p["cols"], p["vals"],
                            docs_chunk=dc))
        np.testing.assert_array_equal(x_c, x_base, err_msg=f"type1 dc={dc}")
        w_c = np.asarray(t2(p["k_pad"], p["km_pad"], p["u"], p["cols"],
                            p["vals"], docs_chunk=dc))
        np.testing.assert_array_equal(w_c, w_base, err_msg=f"type2 dc={dc}")


def test_chunked_solver_matches_unchunked(batch_problem):
    """Full batched solver: chunked == unchunked to fp32 tolerance (whole-
    program XLA fusion may reassociate neighbouring ops per chunk shape)."""
    p = batch_problem
    kw = dict(row_mask=jnp.asarray(p["mask_b"]))
    base = np.asarray(sinkhorn_wmd_sparse_batch(*_solver_args(p), **kw))
    for dc in (8, 16, 45):
        got = np.asarray(sinkhorn_wmd_sparse_batch(*_solver_args(p), **kw,
                                                   docs_chunk=dc))
        err = np.abs(got - base).max() / np.abs(base).max()
        assert err < 1e-5, (dc, err)


# ---------------------------------------------------------------------------
# Early-exit convergence
# ---------------------------------------------------------------------------

def test_early_exit_full_budget_is_exact(batch_problem):
    """When the tolerance forces full iterations (tol = 0), the early-exit
    loop returns the fixed-max_iter solver's result exactly and the per-query
    counters show every iteration executed."""
    p = batch_problem
    kw = dict(row_mask=jnp.asarray(p["mask_b"]))
    fixed = np.asarray(sinkhorn_wmd_sparse_batch(*_solver_args(p), **kw))
    out = sinkhorn_wmd_converged_batch(*_solver_args(p), tol=0.0, **kw)
    np.testing.assert_array_equal(np.asarray(out.wmd), fixed)
    np.testing.assert_array_equal(np.asarray(out.n_iter), ITERS)


def test_early_exit_fewer_iterations_same_result(batch_problem):
    """Easy-convergence workload: the early-exit solver executes strictly
    fewer iterations (per the counter) yet matches the fixed-budget solve
    to fp32 tolerance."""
    p = batch_problem
    budget = 300
    kw = dict(row_mask=jnp.asarray(p["mask_b"]))
    args = _solver_args(p)[:-1] + (budget,)
    fixed = np.asarray(sinkhorn_wmd_sparse_batch(*args, **kw))
    out = sinkhorn_wmd_converged_batch(*args, tol=1e-5, **kw)
    n_iter = np.asarray(out.n_iter)
    assert n_iter.max() < budget, n_iter
    err = (np.abs(np.asarray(out.wmd) - fixed).max() / np.abs(fixed).max())
    assert err < 1e-4, err
    # explicit tol through the jitted fixed-budget solver (regression: tol
    # is branched on in Python, so it must be a static argument)
    early = np.asarray(sinkhorn_wmd_sparse_batch(*args, **kw, tol=1e-5,
                                                 docs_chunk=16))
    err2 = np.abs(early - fixed).max() / np.abs(fixed).max()
    assert err2 < 1e-4, err2


# ---------------------------------------------------------------------------
# Distributed convergence vote
# ---------------------------------------------------------------------------

def test_distributed_vote_matches_single_host_masking():
    """build_wmd_batch_fn(tol>0) on a (2, 2) mesh: per-query n_iter from the
    all-shards vote == single-host sinkhorn_wmd_converged_batch, and the
    distances agree (subprocess: needs a forced device count)."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import (select_query, ell_from_dense,
                        rebucket_for_vocab_shards,
                        sinkhorn_wmd_converged_batch)
from repro.core.distributed import (build_wmd_batch_fn, pad_query_batch,
                                    shard_wmd_inputs)
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(5)
V, w, N = 256, 32, 64
vecs = rng.normal(size=(V, w)).astype(np.float32)
c = np.zeros((V, N), np.float32)
for j in range(N):
    widx = rng.choice(V, rng.integers(3, 17), replace=False)
    c[widx, j] = rng.random(widx.size).astype(np.float32)
    c[:, j] /= c[:, j].sum()
ell = ell_from_dense(c)
queries = []
for vrn in (5, 9, 14):
    r = np.zeros(V, np.float32)
    idx = rng.choice(V, vrn, replace=False)
    r[idx] = rng.random(vrn).astype(np.float32); r /= r.sum()
    queries.append(r)
sels, rsels = zip(*[select_query(r) for r in queries])
sel_b, r_b, mask_b = pad_query_batch(sels, rsels, 16)
ref = sinkhorn_wmd_converged_batch(
    jnp.asarray(sel_b), jnp.asarray(r_b), jnp.asarray(ell.cols),
    jnp.asarray(ell.vals), vecs, 1.0, 400, tol=1e-5,
    row_mask=jnp.asarray(mask_b))
assert int(np.asarray(ref.n_iter).max()) < 400   # masking engaged
rb = rebucket_for_vocab_shards(ell, 2)
fn = build_wmd_batch_fn(mesh, lamb=1.0, max_iter=400, tol=1e-5,
                        docs_chunk=16, chunk_placement="iteration",
                        with_info=True)
vd, cd, vld = shard_wmd_inputs(mesh, vecs, rb.cols, rb.vals)
wmd, n_iter, delta = fn(jnp.asarray(vecs[sel_b]), jnp.asarray(r_b),
                        jnp.asarray(mask_b), vd, cd, vld)
np.testing.assert_array_equal(np.asarray(n_iter), np.asarray(ref.n_iter))
err = (np.abs(np.asarray(wmd) - np.asarray(ref.wmd)).max()
       / np.abs(np.asarray(ref.wmd)).max())
assert err < 1e-4, err
# chunk_placement="solve" (per-chunk freeze): same distances, and no block
# runs longer than the slowest global query
fn2 = build_wmd_batch_fn(mesh, lamb=1.0, max_iter=400, tol=1e-5,
                         docs_chunk=16, with_info=True)
wmd2, n_iter2, _ = fn2(jnp.asarray(vecs[sel_b]), jnp.asarray(r_b),
                       jnp.asarray(mask_b), vd, cd, vld)
err2 = (np.abs(np.asarray(wmd2) - np.asarray(ref.wmd)).max()
        / np.abs(np.asarray(ref.wmd)).max())
assert err2 < 1e-4, err2
assert np.asarray(n_iter2).max() <= np.asarray(ref.n_iter).max()
print("DIST_VOTE_OK", err, err2)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(repo, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "DIST_VOTE_OK" in out.stdout


# ---------------------------------------------------------------------------
# Service plumbing
# ---------------------------------------------------------------------------

def _smoke_service(**kw):
    from repro.configs import sinkhorn_wmd as wmd_cfg
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = wmd_cfg.smoke_config()
    data = make_corpus(vocab_size=cfg.vocab_size, embed_dim=cfg.embed_dim,
                       num_docs=cfg.num_docs, num_queries=3,
                       query_words=cfg.v_r - 2, seed=2)
    return WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                      **kw), data


def test_service_q1_routes_to_sequential():
    """The Q=1 admission policy returns exactly the sequential result (it IS
    the sequential path -- no batched overhead for singletons), and is NOT
    taken when the service is configured with an engine the sequential path
    doesn't implement (tol > 0)."""
    svc, data = _smoke_service()
    lone = [data.queries[0]]
    np.testing.assert_array_equal(svc.query_batch(lone),
                                  svc.query_batch_sequential(lone))
    # shortcut: no batched fn (legacy or stripes) was built
    assert not svc._batch_fns and not svc._stripe_fns
    svc_tol, _ = _smoke_service(tol=1e-6)
    got = svc_tol.query_batch(lone)
    assert svc_tol._batch_fns           # early-exit engine actually ran
    seq = svc_tol.query_batch_sequential(lone)
    err = np.abs(got - seq).max() / np.abs(seq).max()
    assert err < 1e-4, err


@pytest.mark.kernel
def test_service_forwards_impl_and_chunk():
    """query_batch(impl=...) and the docs_chunk/tol fields reach the engine:
    every combination matches the sequential oracle."""
    svc, data = _smoke_service(docs_chunk=16, tol=1e-6)
    seq = svc.query_batch_sequential(data.queries)
    for impl in ("fused", "kernel"):
        got = svc.query_batch(data.queries, impl=impl)
        err = np.abs(got - seq).max() / np.abs(seq).max()
        assert err < 1e-4, (impl, err)
    # per-call docs_chunk override (0 = explicitly unchunked)
    got = svc.query_batch(data.queries, docs_chunk=0)
    err = np.abs(got - seq).max() / np.abs(seq).max()
    assert err < 1e-4, err
    # an explicit impl override bypasses the Q=1 sequential shortcut and
    # still matches the per-query result
    lone = [data.queries[0]]
    got1 = svc.query_batch(lone, impl="kernel")
    assert got1.shape == (1, seq.shape[1])
    err1 = np.abs(got1 - seq[:1]).max() / np.abs(seq[:1]).max()
    assert err1 < 1e-4, err1
