"""Golden regression table: one tiny deterministic corpus, every engine
route, checked-in expected outputs asserted BITWISE.

The cross-engine tests (batched == sequential, cache on == off, pruned ==
scan, kernel == oracle, live pruned == live scan) catch routes drifting from
*each other*; what they
cannot catch is every route drifting *together* -- a silent change to the
shared math (precompute, safe_recip, iteration order) would ship unnoticed.
This table pins the absolute values: any PR that changes a single bit of
any route's output on the fixed corpus fails exactly one obvious test.

Routes pinned: dense oracle, sparse single-query (fused / unfused /
kernel), batched (fused / chunked / kernel), the stripes+K-cache engine,
the service's legacy engine, the RWMD bound prefilter, and the pruned
top-k (ids + distances).

Regeneration (after an *intentional* numerical change, or a jax/XLA
upgrade that re-tiles a kernel -- bitwise pins are per-toolchain):

    PYTHONPATH=src python tests/test_golden.py --regen

then eyeball the diff of `np.load` summaries and commit the new npz with
the justification in the PR description.
"""
import functools
import os

import jax.numpy as jnp
import numpy as np

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "wmd_golden.npz")

LAMB, MAX_ITER, V_R_BUCKET, TOP_K = 1.0, 8, 12, 5


@functools.lru_cache(maxsize=1)
def _corpus():
    from repro.core import ell_from_dense
    rng = np.random.default_rng(1234)
    v, w, n, q = 96, 8, 24, 3
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        widx = rng.choice(v, rng.integers(3, 10), replace=False)
        c[widx, j] = rng.random(widx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    rs = []
    for i in range(q):
        r = np.zeros(v, np.float32)
        idx = rng.choice(v, 5 + 2 * i, replace=False)   # mixed v_r
        r[idx] = rng.random(idx.size).astype(np.float32) + 0.1
        r /= r.sum()
        rs.append(r)
    return vecs, ell_from_dense(c), rs


@functools.lru_cache(maxsize=1)
def _routes() -> dict:
    """Recompute every pinned route on the fixed corpus."""
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.core import (assemble_m_stripes, rwmd_bound_batch,
                            select_query, sinkhorn_wmd_dense,
                            sinkhorn_wmd_sparse, sinkhorn_wmd_sparse_batch)
    from repro.core.distributed import pad_query_batch
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService

    vecs, ell, rs = _corpus()
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    vecs_j = jnp.asarray(vecs)
    c_dense = jnp.asarray(ell.to_dense())
    out: dict = {}

    sels, rsels = zip(*[select_query(r) for r in rs])
    out["dense"] = np.stack([
        np.asarray(sinkhorn_wmd_dense(jnp.asarray(s), jnp.asarray(rr),
                                      c_dense, vecs_j, LAMB, MAX_ITER))
        for s, rr in zip(sels, rsels)])
    for impl in ("fused", "unfused", "kernel"):
        out[f"single_{impl}"] = np.stack([
            np.asarray(sinkhorn_wmd_sparse(jnp.asarray(s), jnp.asarray(rr),
                                           cols, vals, vecs_j, LAMB,
                                           MAX_ITER, impl=impl))
            for s, rr in zip(sels, rsels)])

    sel_b, r_b, mask_b = pad_query_batch(sels, rsels, V_R_BUCKET)
    batch_args = (jnp.asarray(sel_b), jnp.asarray(r_b), cols, vals, vecs_j,
                  LAMB, MAX_ITER)
    mask_j = jnp.asarray(mask_b)
    out["batched_fused"] = np.asarray(
        sinkhorn_wmd_sparse_batch(*batch_args, row_mask=mask_j))
    out["batched_chunked"] = np.asarray(
        sinkhorn_wmd_sparse_batch(*batch_args, row_mask=mask_j,
                                  docs_chunk=7))
    out["batched_kernel"] = np.asarray(
        sinkhorn_wmd_sparse_batch(*batch_args, row_mask=mask_j,
                                  impl="kernel"))

    m_pad = assemble_m_stripes(sel_b, mask_b, vecs, rows_bucket=8)
    out["rwmd_bound"] = np.asarray(rwmd_bound_batch(m_pad, cols, vals))

    cfg = WMDConfig(name="golden", vocab_size=vecs.shape[0], embed_dim=8,
                    num_docs=ell.num_docs, nnz_max=ell.nnz_max,
                    v_r=V_R_BUCKET, lamb=LAMB, max_iter=MAX_ITER)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=vecs, ell=ell,
                     cache_capacity=64, prune_chunk=8,
                     bound_docs_chunk=None)
    out["service_stripes"] = svc.query_batch(rs)              # K-cache route
    out["service_transient"] = svc.query_batch(rs, use_cache=False)
    idx_p, d_p = svc.top_k_batch(rs, TOP_K, prune=True)
    out["pruned_topk_idx"] = idx_p
    out["pruned_topk_dist"] = d_p
    idx_s, d_s = svc.top_k_scan_batch(rs, TOP_K)
    out["scan_topk_idx"] = idx_s
    out["scan_topk_dist"] = d_s

    svc_legacy = WMDService(mesh=mesh, cfg=cfg, vecs=vecs, ell=ell)
    out["service_legacy"] = svc_legacy.query_batch(rs)

    # live-corpus routes: the same docs through a WAL-backed LiveCorpus,
    # three assembly histories that must all land on identical bits --
    # the incremental == batch contract pinned absolutely. normalize=False
    # because doc_lists_from_ell hands back already-normalized weights.
    import tempfile

    from repro.core import formats as fmt
    from repro.data.live_corpus import LiveCorpus
    from repro.serving.faultinject import CrashInjector, InjectedCrash

    docs = fmt.doc_lists_from_ell(ell)
    v = vecs.shape[0]

    def live_service(lc):
        return WMDService(mesh=mesh, cfg=cfg, vecs=vecs, live=lc,
                          cache_capacity=64, prune_chunk=8,
                          bound_docs_chunk=None)

    # one-shot seeding: every doc in a single durable add
    lc = LiveCorpus(tempfile.mkdtemp(prefix="golden-live1-"), v,
                    normalize=False)
    lc.add_docs(range(len(docs)), docs)
    out["live_oneshot"] = live_service(lc).query_batch(rs)

    # incremental assembly: shuffled adds, a wrong doc corrected by
    # upsert, an extraneous doc removed again, a mid-way compaction
    order = list(range(len(docs)))
    np.random.default_rng(7).shuffle(order)
    lc = LiveCorpus(tempfile.mkdtemp(prefix="golden-live2-"), v,
                    normalize=False)
    lc.add_docs([order[0]], [[(0, 1.0)]])          # wrong content first
    for i in order[: len(order) // 2]:
        lc.add_docs([i], [docs[i]])                # (order[0] corrected)
    lc.add_docs([999], [docs[0]])                  # extraneous doc ...
    lc.compact()
    lc.remove_docs([999])                          # ... tombstoned again
    for i in order[len(order) // 2:]:
        lc.add_docs([i], [docs[i]])
    out["live_incremental"] = live_service(lc).query_batch(rs)

    # crash-recovered: killed inside compaction (pre-rename), reopened
    # from WAL replay, finished, then compacted cleanly
    hook = CrashInjector()
    path = tempfile.mkdtemp(prefix="golden-live3-")
    lc = LiveCorpus(path, v, normalize=False, crash_hook=hook)
    for i in order[:16]:
        lc.add_docs([i], [docs[i]])
    hook.target = hook.count + 2                   # compact.snapshot.tmp
    try:
        lc.compact()
        raise AssertionError("injected crash did not fire")
    except InjectedCrash:
        pass
    lc = LiveCorpus(path, v, normalize=False)      # recover from disk
    for i in order[16:]:
        lc.add_docs([i], [docs[i]])
    lc.add_docs([order[0]], [docs[order[0]]])      # upsert to the delta
    lc.compact()
    lsvc = live_service(lc)
    out["live_recovered"] = lsvc.query_batch(rs)

    # live pruned top-k: cascade over the immutable base segment plus an
    # exact-solved delta doc (added after compaction, so the query_batch
    # routes above keep their bits); scan is its exactness oracle
    lc.add_docs([999], [docs[1]])                  # the delta segment
    idx_lp, d_lp = lsvc.top_k_batch(rs, TOP_K, prune=True)
    out["live_pruned_topk_idx"] = idx_lp
    out["live_pruned_topk_dist"] = d_lp
    idx_ls, d_ls = lsvc.top_k_scan_batch(rs, TOP_K)
    out["live_scan_topk_idx"] = idx_ls
    out["live_scan_topk_dist"] = d_ls
    return out


def test_golden_table_bitwise():
    """Every route must reproduce its checked-in table entry bit for bit.

    A failure here means a PR changed the numerics of that route (fix it
    or, if intentional, regenerate -- see the module docstring)."""
    assert os.path.exists(GOLDEN), \
        "golden table missing -- run: python tests/test_golden.py --regen"
    golden = np.load(GOLDEN)
    routes = _routes()
    assert set(golden.files) == set(routes), \
        (set(golden.files) ^ set(routes))
    for name, got in routes.items():
        np.testing.assert_array_equal(
            got, golden[name],
            err_msg=f"route {name!r} drifted from the golden table")


def test_golden_cross_route_consistency():
    """npz-independent sanity: the routes must agree with each other at
    their contracted strengths (bitwise where contracted, fp32 where not),
    so a stale golden file can never mask a real inter-route break."""
    r = _routes()
    # exactness contracts: bitwise
    np.testing.assert_array_equal(r["service_stripes"],
                                  r["service_transient"])
    np.testing.assert_array_equal(r["pruned_topk_idx"], r["scan_topk_idx"])
    np.testing.assert_array_equal(r["pruned_topk_dist"],
                                  r["scan_topk_dist"])
    # the incremental == batch contract: every live-corpus assembly
    # history lands on the frozen service's exact bits
    np.testing.assert_array_equal(r["live_oneshot"], r["service_stripes"])
    np.testing.assert_array_equal(r["live_incremental"], r["live_oneshot"])
    np.testing.assert_array_equal(r["live_recovered"], r["live_oneshot"])
    # the live pruned path (base cascade + exact delta) == its scan oracle
    np.testing.assert_array_equal(r["live_pruned_topk_idx"],
                                  r["live_scan_topk_idx"])
    np.testing.assert_array_equal(r["live_pruned_topk_dist"],
                                  r["live_scan_topk_dist"])
    # engine-vs-engine: fp32
    np.testing.assert_allclose(r["single_fused"], r["dense"],
                               rtol=2e-3, atol=1e-5)
    np.testing.assert_allclose(r["batched_fused"][:3], r["single_fused"],
                               rtol=2e-3, atol=1e-5)
    np.testing.assert_allclose(r["batched_kernel"], r["batched_fused"],
                               rtol=2e-3, atol=1e-5)
    np.testing.assert_allclose(r["service_stripes"], r["batched_fused"][:3],
                               rtol=2e-3, atol=1e-5)
    # the bound is a bound on every route's distances
    for route in ("single_fused", "single_unfused", "batched_fused"):
        d = r[route][:3] if r[route].shape[0] > 3 else r[route]
        assert np.all(r["rwmd_bound"][:3] <= d * (1 + 1e-5) + 1e-6)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the golden table from the current "
                         "toolchain's outputs")
    ap.add_argument("--out", default=GOLDEN,
                    help="regen target path (default: the checked-in "
                         "tests/golden/wmd_golden.npz). CI's freshness "
                         "step regens to a temp path and np.load-compares "
                         "against the checked-in table -- npz zip entries "
                         "carry timestamps, so a byte diff of the files "
                         "is NOT a valid staleness check.")
    args = ap.parse_args()
    if args.regen:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)),
                    exist_ok=True)
        routes = _routes()
        np.savez(args.out, **routes)
        for name, arr in sorted(routes.items()):
            print(f"{name:24s} {str(arr.shape):12s} "
                  f"sum={float(np.asarray(arr, np.float64).sum()):.6f}")
        print(f"wrote {args.out}")
    else:
        print(__doc__)
