"""Layer-level unit tests: blockwise attention vs naive, chunkwise mLSTM vs
recurrent oracle, RG-LRU scan vs step, MoE dispatch + Sinkhorn router, MLA
naive vs absorbed decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import MoEConfig, ModelConfig
from repro.models.layers import moe as moe_mod
from repro.models.layers import rglru as rglru_mod
from repro.models.layers.attention import blockwise_attention
from repro.models.layers.xlstm import mlstm_chunkwise, mlstm_recurrent


def _naive_attn(q, k, v, causal, window, prefix):
    b, tq, kvh, g, hd = q.shape
    tk = k.shape[1]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32) * hd ** -0.5,
                   k.astype(jnp.float32))
    qi = jnp.arange(tq)[:, None]
    ki = jnp.arange(tk)[None, :]
    if causal:
        m = ki <= qi
        if window:
            m &= ki > (qi - window)
        if prefix:
            m |= (ki < prefix) & (qi < prefix)
    else:
        m = jnp.ones((tq, tk), bool)
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4)


@pytest.mark.parametrize("causal,window,prefix",
                         [(True, 0, 0), (True, 32, 0), (True, 0, 24),
                          (False, 0, 0), (True, 48, 0)])
@pytest.mark.parametrize("qb,kb", [(32, 32), (16, 64), (128, 128)])
def test_blockwise_attention(causal, window, prefix, qb, kb):
    rng = np.random.default_rng(0)
    b, t, kvh, g, hd = 2, 128, 2, 3, 16
    q = jnp.asarray(rng.normal(size=(b, t, kvh, g, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kvh, hd)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              prefix_len=prefix, q_block=qb, kv_block=kb)
    ref = _naive_attn(q, k, v, causal, window, prefix)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("chunk", [16, 32, 64, 256])
def test_mlstm_chunkwise_vs_recurrent(chunk):
    rng = np.random.default_rng(3)
    b, h, t, hd = 2, 3, 256, 16
    q = jnp.asarray(rng.normal(size=(b, h, t, hd)), jnp.float32) * hd ** -0.5
    k = jnp.asarray(rng.normal(size=(b, h, t, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, hd)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(b, h, t)), jnp.float32)
    lf = jnp.asarray(np.log(1 / (1 + np.exp(-rng.normal(size=(b, h, t))
                                            - 3))), jnp.float32)
    h_ref, (c_r, n_r, m_r) = mlstm_recurrent(q, k, v, li, lf)
    h_ck, (c_c, n_c, m_c) = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_ck), np.asarray(h_ref),
                               atol=5e-4)
    np.testing.assert_allclose(np.asarray(c_c), np.asarray(c_r), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m_c), np.asarray(m_r), atol=1e-5)


def test_rglru_scan_vs_decode():
    """Associative-scan prefill == step-by-step decode."""
    cfg = get_smoke_config("recurrentgemma-9b")
    params = rglru_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    b, t = 2, 12
    x = jnp.asarray(rng.normal(size=(b, t, cfg.d_model)), jnp.float32)
    y_full, state_full = rglru_mod.fwd_full(cfg, params, x,
                                            return_state=True)
    state = rglru_mod.init_state(cfg, b)
    ys = []
    for i in range(t):
        y, state = rglru_mod.fwd_decode(cfg, params, x[:, i:i + 1], state)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(state.h), np.asarray(state_full.h),
                               atol=1e-4)


def _moe_cfg(router="topk", experts=8, top_k=2, cf=2.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=experts, top_k=top_k, d_ff_expert=24,
                      capacity_factor=cf, router=router))


def test_moe_output_shape_and_grad():
    cfg = _moe_cfg()
    params = moe_mod.init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 32)),
                    jnp.float32)
    out, aux = moe_mod.apply(cfg, params, x)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))

    def loss(p):
        o, a = moe_mod.apply(cfg, p, x)
        return jnp.sum(o * o) + a

    grads = jax.grad(loss)(params)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    # router must receive gradient (it controls dispatch weights)
    assert float(jnp.abs(grads["router"]).max()) > 0


def test_sinkhorn_router_balances_load():
    """The paper's technique as MoE router: expert loads must be far more
    uniform than the topk router's on skewed inputs."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 256, 32)) * 0.2
                    + rng.normal(size=(1, 1, 32)),  # shared bias -> skew
                    jnp.float32)

    def loads(router):
        cfg = _moe_cfg(router=router)
        params = moe_mod.init(jax.random.PRNGKey(1), cfg)
        logits = x.reshape(-1, 32) @ params["router"]
        ids, _, _ = moe_mod._gates(cfg.moe, logits)
        counts = np.bincount(np.asarray(ids).ravel(),
                             minlength=cfg.moe.num_experts)
        return counts / counts.sum()

    l_topk = loads("topk")
    l_sink = loads("sinkhorn")
    # coefficient of variation must shrink substantially
    cv = lambda p: p.std() / p.mean()
    assert cv(l_sink) < 0.5 * cv(l_topk), (l_topk, l_sink)


def test_moe_capacity_drops_bounded():
    """With cf >= num_experts/top_k... actually with generous capacity no
    token output should be exactly zero (nothing dropped)."""
    cfg = _moe_cfg(cf=8.0)
    params = moe_mod.init(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(1, 64, 32)),
                    jnp.float32)
    out, _ = moe_mod.apply(cfg, params, x)
    # every token got at least one expert's contribution
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert float(norms.min()) > 0


def test_mla_absorbed_equals_naive():
    """The absorbed MLA decode (hillclimb) must match the naive decode."""
    from repro.models.layers import mla as mla_mod
    cfg = get_smoke_config("minicpm3-4b")
    params = mla_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    b = 2
    cache1 = mla_mod.init_cache(cfg, b, 8, dtype=jnp.float32)
    cache2 = mla_mod.init_cache(cfg, b, 8, dtype=jnp.float32)
    for t in range(6):
        x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)), jnp.float32)
        y1, cache1 = mla_mod.fwd_decode(cfg, params, x, cache1)
        y2, cache2 = mla_mod.fwd_decode_absorbed(cfg, params, x, cache2)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=2e-5)
