"""Resilience layer: numeric guards, breaker/brownout state machines, and
the seeded chaos suite.

Contracts pinned here (ISSUE 7 acceptance criteria):
  * under a seeded fault schedule (dispatch exceptions, latency spikes,
    corrupted outputs) the serving loop never deadlocks and every
    submitted future resolves exactly once;
  * successful (non-degraded, rung-0) responses are bitwise identical to
    a no-fault dispatch of the same batch composition;
  * availability stays >= 0.99 and the degraded fraction is surfaced in
    ServingStats;
  * the breaker and brownout machines hit every transition;
  * high lambda raises a typed NumericalError where the old engine
    silently returned exact-zero distances (pinned with guards off).

Determinism: faults draw from ``default_rng((seed, call_index))`` -- the
schedule replays identically regardless of thread timing; state-machine
tests run on fake clocks and fake engines (no jax at all).
"""
import dataclasses
import threading
import time

import numpy as np
import pytest

from repro.configs.sinkhorn_wmd import WMDConfig
from repro.core import guards
from repro.data import make_corpus, zipf_query_stream
from repro.distributed.fault_tolerance import FaultPolicy, ServingWatchdog
from repro.launch.mesh import make_mesh
from repro.serving.coalescer import QueryCoalescer
from repro.serving.faultinject import (FaultSchedule, FaultSpec, FaultyEngine,
                                       InjectedFault)
from repro.serving.resilience import (BrownoutController, CircuitBreaker,
                                      DegradedResult, EngineGuard,
                                      ResiliencePolicy)
from repro.serving.wmd_service import WMDService

VOCAB, DOCS = 512, 24


def _service(*, lamb=1.0, capacity=64, guards_on=True, seed=0):
    data = make_corpus(vocab_size=VOCAB, embed_dim=32, num_docs=DOCS,
                       num_queries=1, query_words=11, mean_words=12.0,
                       seed=seed)
    cfg = WMDConfig(name="res", vocab_size=VOCAB, embed_dim=32,
                    num_docs=DOCS, nnz_max=64, v_r=16, lamb=lamb, max_iter=8)
    mesh = make_mesh((1, 1), ("data", "model"))
    return WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                      cache_capacity=capacity, bound_docs_chunk=None,
                      guards=guards_on)


def _queries(n, seed=0):
    stream = zipf_query_stream(vocab_size=VOCAB, query_words=11, s=1.2,
                               seed=seed)
    return [next(stream) for _ in range(n)]


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class FlakyService:
    """jax-free engine stub: fails the first ``fail`` calls per method
    route, records every (method, impl) it was dispatched."""
    impl = "fused"

    def __init__(self, fail=0, n_docs=6):
        self.fail = fail
        self.n_docs = n_docs
        self.calls = []

    def _maybe_fail(self):
        if self.fail > 0:
            self.fail -= 1
            raise RuntimeError("flaky")

    def query_batch(self, rs, impl=None):
        self.calls.append(("query_batch", impl))
        self._maybe_fail()
        return np.ones((len(rs), self.n_docs), np.float32)

    def top_k_batch(self, rs, k=10, prune=False, impl=None):
        self.calls.append(("top_k_batch", "pruned" if prune else "scan",
                           impl))
        self._maybe_fail()
        return (np.zeros((len(rs), k), np.int64),
                np.ones((len(rs), k), np.float32))

    def query_batch_bounds(self, rs):
        self.calls.append(("bounds", None))
        return np.full((len(rs), self.n_docs), 0.5, np.float32)

    def top_k_batch_bounds(self, rs, k=10):
        self.calls.append(("bounds_topk", None))
        return (np.zeros((len(rs), k), np.int64),
                np.full((len(rs), k), 0.5, np.float32))


# ---------------------------------------------------------------------------
# guards: unit level
# ---------------------------------------------------------------------------

def test_validate_query_rejections():
    ok = np.zeros(8, np.float32)
    ok[3] = 1.0
    assert guards.validate_query(ok, 8) is not None
    cases = {
        "wrong length": (np.ones(5, np.float32), 8),
        "2-D": (np.ones((2, 4), np.float32), None),
        "non-finite": (np.array([1, np.nan, 0, 0], np.float32), None),
        "negative": (np.array([1, -1, 0, 0], np.float32), None),
        "all-zero": (np.zeros(8, np.float32), None),
        "non-numeric": (np.array(["a", "b"]), None),
    }
    for name, (bad, v) in cases.items():
        with pytest.raises(guards.InvalidQueryError):
            guards.validate_query(bad, v)


def test_underflow_gate_threshold():
    # gate = lamb * 2 * max_norm >= 149 ln 2 (~103.28)
    assert not guards.underflow_possible(1.0, 7.7)      # every shipped cfg
    assert not guards.underflow_possible(5.0, 7.7)
    assert guards.underflow_possible(30.0, 7.7)
    assert guards.underflow_possible(1.0, 60.0)         # huge embeddings


def test_check_km_rows_masks_pad_rows():
    # (Q=1, v_r=3) row maxes: one real-dead row fires, pad-dead rows don't
    rowmax = np.array([[0.0, 1.0, 0.0]])
    guards.check_km_rows(rowmax, np.array([[0, 1, 0]]))  # dead rows are pad
    with pytest.raises(guards.NumericalError) as ei:
        guards.check_km_rows(rowmax, np.array([[1, 1, 0]]), lamb=42.0)
    assert ei.value.context["check"] == "km_underflow"
    assert ei.value.context["lamb"] == 42.0


def test_check_distances_zero_cells_gated():
    d = np.array([[0.0, 1.0], [2.0, 3.0]], np.float32)
    guards.check_distances(d, risk=False)               # gate off: fine
    with pytest.raises(guards.NumericalError):
        guards.check_distances(d, risk=True)
    # empty docs legitimately solve to 0 even under an armed gate
    guards.check_distances(d, risk=True,
                           empty_doc_mask=np.array([True, False]))
    with pytest.raises(guards.NumericalError):          # non-finite always
        guards.check_distances(np.array([np.inf]), risk=False)


# ---------------------------------------------------------------------------
# breaker / brownout / backoff state machines (fake clocks, no jax)
# ---------------------------------------------------------------------------

def test_circuit_breaker_every_transition():
    clk = FakeClock()
    br = CircuitBreaker(failures=3, cooldown_s=5.0, probes=2, clock=clk)
    assert br.state == "closed" and br.allow()
    br.record_failure()
    br.record_failure()
    assert br.state == "closed"                 # streak below threshold
    br.record_failure()
    assert br.state == "open" and not br.allow()
    clk.advance(5.1)
    assert br.allow() and br.state == "half_open"
    br.record_failure()                         # failed probe -> re-open
    assert br.state == "open"
    clk.advance(5.1)
    assert br.allow() and br.state == "half_open"
    br.record_success()
    assert br.state == "half_open"              # needs 2 probes
    br.record_success()
    assert br.state == "closed"
    assert set(br.transitions) == {("closed", "open"),
                                   ("open", "half_open"),
                                   ("half_open", "open"),
                                   ("half_open", "closed")}


def test_circuit_breaker_success_resets_streak():
    br = CircuitBreaker(failures=2, clock=FakeClock())
    br.record_failure()
    br.record_success()
    br.record_failure()
    assert br.state == "closed"                 # streak was reset


def test_brownout_hysteresis_and_dwell():
    clk = FakeClock()
    bo = BrownoutController(queue_hi=10, queue_lo=2, miss_hi=0.5,
                            miss_lo=0.1, dwell_s=1.0, clock=clk)
    assert not bo.update(5, 0.0)                # below hi
    assert bo.update(10, 0.0) and bo.entries == 1
    clk.advance(0.5)
    assert bo.update(0, 0.0)                    # calm but dwell not served
    clk.advance(0.6)
    assert bo.update(3, 0.0)                    # dwell served, NOT calm yet
    assert not bo.update(2, 0.0)                # calm + dwell -> exit
    assert bo.update(0, 0.9) and bo.entries == 2   # miss signal re-enters
    clk.advance(1.1)
    assert bo.update(0, 0.2)                    # miss still above lo
    assert not bo.update(0, 0.1)


def test_brownout_disabled_without_thresholds():
    bo = BrownoutController(clock=FakeClock())
    assert not bo.update(10 ** 9, 1.0)


def test_backoff_bounded_and_positive():
    g = EngineGuard(FlakyService(), ResiliencePolicy(
        backoff_base_s=0.01, backoff_mult=2.0, backoff_max_s=0.05,
        backoff_jitter=0.5, seed=3), sleep=lambda s: None)
    waits = [g._backoff(a) for a in range(10)]
    assert all(0.0 < w <= 0.05 * 1.5 for w in waits)
    assert waits[1] >= 0.01                     # base grows with attempts


# ---------------------------------------------------------------------------
# fault schedule determinism
# ---------------------------------------------------------------------------

def test_fault_schedule_deterministic_and_windowed():
    s1 = FaultSchedule(seed=5, p_error=0.3, p_latency=0.3, p_corrupt=0.3)
    s2 = FaultSchedule(seed=5, p_error=0.3, p_latency=0.3, p_corrupt=0.3)
    draws1 = [s1.faults_for(i) for i in range(200)]
    assert draws1 == [s2.faults_for(i) for i in range(200)]
    assert any(f.error for f in draws1) and any(f.corrupt for f in draws1)
    assert [s1.faults_for(i) for i in range(200)] == draws1   # stateless
    sw = FaultSchedule(seed=5, p_error=1.0, window=(10, 12))
    assert not sw.faults_for(9).error
    assert sw.faults_for(10).error and sw.faults_for(11).error
    assert not sw.faults_for(12).error


def test_fault_schedule_from_events():
    sched = FaultSchedule.from_events({3: FaultSpec(error=True),
                                       5: FaultSpec(corrupt=True)})
    assert sched.faults_for(3).error
    assert sched.faults_for(5).corrupt
    assert sched.faults_for(4) == FaultSpec()


# ---------------------------------------------------------------------------
# EngineGuard: retry, demotion, recovery, degradation (fake engine)
# ---------------------------------------------------------------------------

def test_retry_recovers_transient_failures():
    svc = FlakyService(fail=2)
    g = EngineGuard(svc, ResiliencePolicy(max_retries=2, breaker_failures=5),
                    sleep=lambda s: None)
    res = g.dispatch("plain", [np.ones(4)] * 2)
    assert isinstance(res, np.ndarray) and res.shape == (2, 6)
    st = g.stats()
    assert st.retries == 2 and st.failures == 2 and st.demoted == 0
    # rung 0 dispatches with impl=None: the exact unguarded call
    assert svc.calls[-1] == ("query_batch", None)


def test_demotion_and_breaker_recovery():
    clk = FakeClock()
    svc = FlakyService(fail=1)
    g = EngineGuard(svc, ResiliencePolicy(
        max_retries=0, breaker_failures=2, breaker_cooldown_s=10.0),
        clock=clk, sleep=lambda s: None)
    res = g.dispatch("plain", [np.ones(4)])
    # retries=0: rung 0 fails once (breaker streak 1), demote to rung 1
    # ("unfused"), which succeeds
    assert isinstance(res, np.ndarray)
    assert ("query_batch", "unfused") in svc.calls
    st = g.stats()
    assert st.demoted == 1
    # fail rung 0 once more -> streak 2 -> breaker opens
    svc.fail = 1
    g.dispatch("plain", [np.ones(4)])
    assert g.stats().breaker_states["plain/0"] == "open"
    # while open, dispatches skip rung 0 entirely
    n_calls = len(svc.calls)
    g.dispatch("plain", [np.ones(4)])
    assert svc.calls[n_calls:] == [("query_batch", "unfused")]
    # cooldown passes: next dispatch probes rung 0 (half_open) and closes
    clk.advance(10.1)
    g.dispatch("plain", [np.ones(4)])
    assert svc.calls[-1] == ("query_batch", None)
    assert g.stats().breaker_states["plain/0"] == "closed"


def test_top_k_ladder_falls_back_to_scan():
    svc = FlakyService(fail=2)                  # pruned rungs: None, unfused
    g = EngineGuard(svc, ResiliencePolicy(max_retries=0, breaker_failures=1,
                                          degrade_on_failure=False),
                    sleep=lambda s: None)
    res = g.dispatch("top_k", [np.ones(4)], k=3)
    assert res[0].shape == (1, 3)
    kinds = [c[1] for c in svc.calls if c[0] == "top_k_batch"]
    assert kinds == ["pruned", "pruned", "scan"]


def test_degraded_when_every_rung_fails():
    svc = FlakyService(fail=100)
    g = EngineGuard(svc, ResiliencePolicy(max_retries=1, breaker_failures=2),
                    sleep=lambda s: None)
    res = g.dispatch("plain", [np.ones(4)] * 3)
    assert isinstance(res, DegradedResult)
    assert res.tier == "rwmd_bound"
    assert "engine_failure" in res.reason and "flaky" in res.reason
    np.testing.assert_array_equal(res.value,
                                  np.full((3, 6), 0.5, np.float32))
    st = g.stats()
    assert st.degraded == 1 and st.degraded_requests == 3


def test_degradation_disabled_raises_last_error():
    svc = FlakyService(fail=100)
    g = EngineGuard(svc, ResiliencePolicy(max_retries=0, breaker_failures=1,
                                          degrade_on_failure=False),
                    sleep=lambda s: None)
    with pytest.raises(RuntimeError, match="flaky"):
        g.dispatch("plain", [np.ones(4)])


def test_invalid_query_never_retried():
    class Rejecting(FlakyService):
        def query_batch(self, rs, impl=None):
            self.calls.append(("query_batch", impl))
            raise guards.InvalidQueryError("bad row")

    svc = Rejecting()
    g = EngineGuard(svc, ResiliencePolicy(max_retries=5),
                    sleep=lambda s: None)
    with pytest.raises(guards.InvalidQueryError):
        g.dispatch("plain", [np.ones(4)])
    assert len(svc.calls) == 1                  # no retry, no demotion
    assert g.stats().retries == 0


def test_guard_post_check_catches_corruption():
    class Corrupting(FlakyService):
        def query_batch(self, rs, impl=None):
            self.calls.append(("query_batch", impl))
            out = np.ones((len(rs), self.n_docs), np.float32)
            if len(self.calls) == 1:            # only the first dispatch
                out[0, 0] = np.nan
            return out

    svc = Corrupting()
    g = EngineGuard(svc, ResiliencePolicy(max_retries=2, breaker_failures=5),
                    sleep=lambda s: None)
    res = g.dispatch("plain", [np.ones(4)])
    assert np.isfinite(res).all()               # retry returned clean data
    assert g.stats().retries == 1


def test_brownout_dispatch_serves_bounds_and_recovers():
    clk = FakeClock()
    svc = FlakyService()
    g = EngineGuard(svc, ResiliencePolicy(
        brownout_queue_hi=4, brownout_queue_lo=1, brownout_dwell_s=1.0),
        clock=clk, sleep=lambda s: None)
    res = g.dispatch("plain", [np.ones(4)], queue_depth=10)
    assert isinstance(res, DegradedResult) and res.reason == "brownout"
    clk.advance(1.1)
    res = g.dispatch("plain", [np.ones(4)], queue_depth=0)
    assert isinstance(res, np.ndarray)          # calm + dwell: exact again
    assert g.stats().brownout_entries == 1


def test_trip_force_opens_active_rung():
    svc = FlakyService()
    g = EngineGuard(svc, ResiliencePolicy(), sleep=lambda s: None)
    g.trip("plain")
    assert g.stats().breaker_states["plain/0"] == "open"
    g.dispatch("plain", [np.ones(4)])           # served by rung 1
    assert svc.calls[-1] == ("query_batch", "unfused")
    g.trip("plain")                             # next non-open rung
    assert g.stats().breaker_states["plain/1"] == "open"


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_watchdog_straggler_strikes_trip():
    clk = FakeClock()
    tripped = []
    wd = ServingWatchdog(FaultPolicy(straggler_factor=2.0,
                                     straggler_strikes=3),
                         on_strike=tripped.append, min_samples=3, clock=clk)
    for _ in range(5):
        wd.beat("plain", 0.01, True)            # establish the median
    for _ in range(2):
        wd.beat("plain", 0.1, True)             # 2 strikes: below threshold
    assert tripped == []
    wd.beat("plain", 0.01, True)                # fast beat resets the streak
    for _ in range(3):
        wd.beat("plain", 0.1, True)
    assert tripped == ["plain"]                 # 3 consecutive -> trip
    assert wd.report()["plain"]["tripped"] == 1


def test_watchdog_failures_count_as_strikes():
    tripped = []
    wd = ServingWatchdog(FaultPolicy(straggler_strikes=2),
                         on_strike=tripped.append, clock=FakeClock())
    wd.beat("top_k", 0.01, False)
    wd.beat("top_k", 0.01, False)
    assert tripped == ["top_k"]
    assert wd.report()["top_k"]["failures"] == 2


def test_watchdog_liveness_needs_pending_work():
    clk = FakeClock()
    pending = {"n": 0}
    wd = ServingWatchdog(FaultPolicy(timeout_s=5.0),
                         pending_fn=lambda: pending["n"], clock=clk)
    wd.beat("plain", 0.01, True)
    clk.advance(10.0)
    assert wd.check() == []                     # idle silence is fine
    pending["n"] = 3
    assert wd.check() == ["plain"]              # silent with a backlog
    wd.beat("plain", 0.01, True)
    assert wd.check() == []


# ---------------------------------------------------------------------------
# admission validation at the coalescer
# ---------------------------------------------------------------------------

def test_admission_quarantines_bad_queries():
    svc = _service()
    with svc.async_service(window_ms=1.0, max_batch=4) as co:
        good = _queries(3)
        bad = [np.full(VOCAB, np.nan, np.float32),
               -np.ones(VOCAB, np.float32),
               np.zeros(VOCAB, np.float32),
               np.ones(7, np.float32)]
        futs = [co.submit(q) for q in good]
        for b in bad:
            with pytest.raises(guards.InvalidQueryError):
                co.submit(b)
        rows = [f.result(timeout=60) for f in futs]
    st = co.stats()
    assert st.quarantined == len(bad)
    assert st.completed == len(good) and st.failed == 0
    assert all(np.isfinite(r).all() for r in rows)
    # quarantined requests never reached a dispatch
    assert sum(len(b) for b in co.batch_log) == len(good)


def test_fake_services_keep_light_validation():
    class Fake:
        def query_batch(self, rs):
            return np.zeros((len(rs), 2), np.float32)

    co = QueryCoalescer(Fake(), window_ms=1.0, max_batch=2)
    try:
        f = co.submit(np.zeros(4, np.float32))   # all-zero: fine for fakes
        f.result(timeout=10)
        with pytest.raises(guards.InvalidQueryError):
            co.submit(np.full(4, np.inf, np.float32))   # non-finite: not
    finally:
        co.shutdown()


# ---------------------------------------------------------------------------
# high-lambda underflow: typed error vs the old silent-zero behavior
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["fused", "unfused"])
@pytest.mark.parametrize("capacity", [0, 64])
def test_high_lambda_raises_numerical_error(impl, capacity):
    svc = _service(lamb=30.0, capacity=capacity)
    svc.impl = impl
    qs = _queries(4, seed=1)
    with pytest.raises(guards.NumericalError) as ei:
        svc.query_batch(qs)
    assert ei.value.context["check"] in ("km_underflow", "zero_distance")
    # the old behavior, pinned: guards off -> silent exact-zero distances
    svc.guards = False
    d = svc.query_batch(qs)
    assert np.isfinite(d).all() and (d == 0.0).any()


def test_default_lambda_unchanged_by_guards():
    qs = _queries(4, seed=2)
    d_on = _service(guards_on=True).query_batch(qs)
    d_off = _service(guards_on=False).query_batch(qs)
    np.testing.assert_array_equal(d_on, d_off)  # guards are read-only


def test_degraded_tier_survives_high_lambda():
    # lambda kills the exact tier but not the bound tier (M has no exp):
    # the resilient path keeps answering, degraded
    svc = _service(lamb=30.0)
    g = EngineGuard(svc, ResiliencePolicy(max_retries=0, breaker_failures=1),
                    sleep=lambda s: None)
    res = g.dispatch("plain", _queries(2, seed=3))
    assert isinstance(res, DegradedResult)
    assert np.isfinite(res.value).all()


# ---------------------------------------------------------------------------
# chaos suite: the serving loop under a seeded fault schedule
# ---------------------------------------------------------------------------

CHAOS_POLICY = ResiliencePolicy(
    max_retries=3, breaker_failures=4, breaker_cooldown_s=0.05,
    backoff_base_s=0.001, backoff_max_s=0.01, seed=0)


def _run_chaos(svc, qs, schedule, *, policy=CHAOS_POLICY, top_k=None,
               window_ms=1.0, max_batch=4, concurrency=0):
    eng = FaultyEngine(svc, schedule)
    co = QueryCoalescer(eng, window_ms=window_ms, max_batch=max_batch,
                        resilience=policy)
    futs = []
    try:
        if concurrency:
            from repro.serving.loadgen import closed_loop
            submit = (co.submit if top_k is None
                      else lambda r: co.submit_top_k(r, top_k))
            lg = closed_loop(submit, qs, concurrency=concurrency,
                             keep_results=True)
            return co, eng, lg, None
        submit = (co.submit if top_k is None
                  else lambda r: co.submit_top_k(r, top_k))
        futs = [submit(q) for q in qs]
        co.drain(timeout=120.0)                 # the no-deadlock assertion
        return co, eng, None, futs
    finally:
        co.shutdown(drain=True, timeout=120.0)


def test_chaos_no_deadlock_every_future_resolves_bitwise():
    svc = _service()
    qs = _queries(48, seed=4)
    sched = FaultSchedule(seed=11, p_error=0.2, p_latency=0.15,
                          p_corrupt=0.1, latency_s=0.005)
    co, eng, _, futs = _run_chaos(svc, qs, sched)
    # every submitted future resolved exactly once, with a result
    assert all(f.done() for f in futs)
    exact = degraded = 0
    for f in futs:
        assert f.exception() is None
        r = f.result()
        if isinstance(r, DegradedResult):
            degraded += 1
            r = r.value
        else:
            exact += 1
        assert r.shape == (DOCS,) and np.isfinite(r).all()
    st = co.stats()
    assert st.completed == len(qs) and st.failed == 0
    availability = (st.submitted - st.failed) / st.submitted
    assert availability >= 0.99
    assert st.degraded == degraded
    assert st.degraded_fraction == degraded / len(qs)
    assert eng.injected["error"] > 0            # the schedule actually bit
    # bitwise contract: every clean rung-0 dispatch the injector saw must
    # equal a no-fault dispatch of the same composition on a clean service
    clean = _service()
    replayed = 0
    for rec in eng.dispatch_log:
        if (rec.method == "query_batch" and rec.result is not None
                and not rec.fault.corrupt and "impl" not in rec.kwargs):
            np.testing.assert_array_equal(
                rec.result, clean.query_batch(rec.payloads))
            replayed += 1
    assert replayed > 0


def test_chaos_closed_loop_top_k():
    svc = _service()
    qs = _queries(24, seed=5)
    sched = FaultSchedule(seed=13, p_error=0.15, p_corrupt=0.1)
    co, eng, lg, _ = _run_chaos(svc, qs, sched, top_k=5, concurrency=3)
    assert lg.submitted == len(qs)
    assert lg.completed + lg.failed == len(qs)
    assert lg.completed / lg.submitted >= 0.99
    st = co.stats()
    assert st.completed == lg.completed
    for res in lg.results:
        if isinstance(res, DegradedResult):
            res = res.value
        idx, dist = res
        assert idx.shape == (5,) and np.isfinite(dist).all()


def test_chaos_open_loop_poisson():
    """Open-loop Poisson arrivals through the injector: offered load does
    not pause for faults, yet availability holds."""
    from repro.serving.loadgen import open_loop
    svc = _service()
    qs = _queries(24, seed=10)
    eng = FaultyEngine(svc, FaultSchedule(seed=29, p_error=0.2,
                                          p_corrupt=0.1))
    co = QueryCoalescer(eng, window_ms=1.0, max_batch=4,
                        resilience=CHAOS_POLICY)
    try:
        lg = open_loop(co.submit, iter(qs), rate_qps=2000.0,
                       keep_results=True)
    finally:
        co.shutdown(drain=True, timeout=120.0)
    assert lg.submitted == len(qs)
    assert lg.completed + lg.failed == len(qs)
    assert lg.completed / lg.submitted >= 0.99
    for res in lg.results:
        if isinstance(res, DegradedResult):
            res = res.value
        assert np.isfinite(res).all()


def test_chaos_fault_storm_recovers():
    """A 100%-error storm window opens breakers and serves degraded; after
    the storm (and the breaker cooldown), probes close the breakers and
    exact serving resumes."""
    svc = _service()
    qs = _queries(40, seed=6)
    # calls 4..16 all fail -- enough to burn every rung's retry budget
    sched = FaultSchedule(seed=17, p_error=1.0, window=(4, 16))
    policy = dataclasses.replace(CHAOS_POLICY, max_retries=1,
                                 breaker_failures=2,
                                 breaker_cooldown_s=0.02)
    eng = FaultyEngine(svc, sched)
    co = QueryCoalescer(eng, window_ms=1.0, max_batch=4, resilience=policy)
    try:
        futs = [co.submit(q) for q in qs]
        co.drain(timeout=120.0)
        assert all(f.done() and f.exception() is None for f in futs)
        assert any(isinstance(f.result(), DegradedResult) for f in futs)
        time.sleep(0.05)                        # > cooldown: breakers cool
        eng.schedule = FaultSchedule()          # storm over
        post = [co.submit(q) for q in _queries(4, seed=60)]
        co.drain(timeout=120.0)
        for f in post:                          # exact serving resumed
            assert isinstance(f.result(), np.ndarray)
    finally:
        co.shutdown(drain=True, timeout=120.0)
    st = co.stats()
    assert st.completed == len(qs) + 4 and st.failed == 0
    assert st.breaker_transitions >= 2          # open + recovery


def test_chaos_brownout_integration():
    """Latency injection builds a backlog; the brownout controller flips
    the coalescer to bound-only responses (marked, counted, bitwise equal
    to a bounds replay of the same composition) until the queue clears."""
    svc = _service()
    qs = _queries(24, seed=7)
    sched = FaultSchedule(seed=19, p_latency=1.0, latency_s=0.02)
    policy = dataclasses.replace(CHAOS_POLICY, brownout_queue_hi=2,
                                 brownout_queue_lo=0, brownout_dwell_s=0.0)
    co, eng, _, futs = _run_chaos(svc, qs, sched, policy=policy,
                                  window_ms=30.0)
    assert all(f.done() and f.exception() is None for f in futs)
    st = co.stats()
    assert st.completed == len(qs) and st.failed == 0
    assert st.degraded > 0
    assert co.guard.stats().brownout_entries >= 1
    # degraded responses are bitwise a bounds dispatch of the same batch
    clean = _service()
    seq_to_q = dict(enumerate(qs))
    degraded_checked = 0
    for batch in co.batch_log:
        rows = [futs[s].result() for s in batch]
        if not all(isinstance(r, DegradedResult) for r in rows):
            continue
        ref = clean.query_batch_bounds([seq_to_q[s] for s in batch])
        for i, r in enumerate(rows):
            np.testing.assert_array_equal(r.value, ref[i])
            assert r.tier == "rwmd_bound" and r.reason == "brownout"
            degraded_checked += 1
    assert degraded_checked > 0


def test_chaos_stats_clean_run_has_no_resilience_noise():
    svc = _service()
    qs = _queries(8, seed=8)
    co, eng, _, futs = _run_chaos(svc, qs, FaultSchedule())   # no faults
    st = co.stats()
    assert st.retries == 0 and st.degraded == 0 and st.quarantined == 0
    assert st.breaker_transitions == 0 and not st.brownout_active
    # and fault-free resilient serving is bitwise the plain engine
    clean = _service()
    for rec in eng.dispatch_log:
        np.testing.assert_array_equal(
            rec.result, clean.query_batch(rec.payloads))


def test_faulty_engine_protects_bounds_tier():
    svc = FlakyService()
    eng = FaultyEngine(svc, FaultSchedule(seed=1, p_error=1.0))
    with pytest.raises(InjectedFault):
        eng.query_batch([np.ones(4)])
    # bounds are exempt from injection by default (the brownout fallback
    # must stay reliable while the exact tier burns)
    np.testing.assert_array_equal(eng.query_batch_bounds([np.ones(4)]),
                                  np.full((1, 6), 0.5, np.float32))


def test_dispatcher_survives_concurrent_chaos_submitters():
    """Multiple client threads + faults: no deadlock, exact accounting."""
    svc = _service()
    qs = _queries(30, seed=9)
    eng = FaultyEngine(svc, FaultSchedule(seed=23, p_error=0.2))
    co = QueryCoalescer(eng, window_ms=1.0, max_batch=4,
                        resilience=CHAOS_POLICY)
    futs = [None] * len(qs)

    def client(lo, hi):
        for i in range(lo, hi):
            futs[i] = co.submit(qs[i])

    threads = [threading.Thread(target=client, args=(i * 10, (i + 1) * 10))
               for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        co.drain(timeout=120.0)
    finally:
        co.shutdown(drain=True, timeout=120.0)
    assert all(f is not None and f.done() for f in futs)
    st = co.stats()
    assert st.submitted == len(qs)
    assert st.completed + st.failed == len(qs)
    assert st.completed / st.submitted >= 0.99
