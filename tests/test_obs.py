"""Observability layer: metrics registry, per-request tracing, exporters.

Contracts pinned here (ISSUE 9 acceptance criteria):
  * the registry is a correct concurrent store: exact totals under
    thread contention, get-or-create identity, kind-mismatch rejection;
  * Prometheus text exposition conforms to the 0.0.4 grammar (HELP/TYPE
    lines, sample lines, cumulative histogram buckets ending at +Inf)
    and is actually scrapeable over HTTP;
  * every submitted request -- including quarantined, cancelled,
    degraded and failed ones, under a seeded chaos schedule -- ends as
    exactly ONE closed span tree, with no trees left open;
  * breaker transitions, brownout enter/exit, watchdog strikes, WAL
    appends and compaction boundaries all land in the structured event
    log;
  * observability on is bitwise identical to observability off on the
    golden routes (tracing never touches result arrays);
  * ``last_batch_stats`` is never cleared to ``{}``: the sequential and
    legacy-fused routes report total solve wall time with an explicit
    ``phases_separable: False`` marker.
"""
import json
import re
import threading
import urllib.request

import numpy as np
import pytest

from repro.obs import (DEFAULT_SIZE_BUCKETS, JsonlExporter, MetricsRegistry,
                       MetricsServer, NULL_TRACER, Tracer, render_prometheus)
from repro.serving.coalescer import QueryCoalescer
from repro.serving.resilience import (DegradedResult, EngineGuard,
                                      ResiliencePolicy)

# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help text")
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(2.5)
    g.inc()
    g.dec(0.5)
    assert g.value == 3.0
    h = reg.histogram("h_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.1, 2.0):     # 0.1 lands in le=0.1 (inclusive)
        h.observe(v)
    cum = h.cumulative()
    assert cum == [(0.1, 2), (1.0, 3), (float("inf"), 4)]
    assert h.sum == pytest.approx(2.65)
    assert h.count == 4


def test_registry_get_or_create_identity_and_kind_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x_total", labels={"op": "plain"})
    b = reg.counter("x_total", labels={"op": "plain"})
    other = reg.counter("x_total", labels={"op": "top_k"})
    assert a is b and a is not other
    a.inc(2)
    with pytest.raises(TypeError):
        reg.gauge("x_total", labels={"op": "plain"})
    reg.histogram("h_seconds").observe(0.2)
    snap = reg.snapshot()
    json.dumps(snap)                    # strictly JSON-able (incl. +Inf)
    assert snap["x_total{op=plain}"] == 2.0
    assert snap["h_seconds"]["buckets"][-1][0] == "+Inf"


def test_registry_thread_safety_exact_totals():
    """N concurrent dispatchers hammering shared + private counters must
    lose no increment -- the single-backing-store contract."""
    reg = MetricsRegistry()
    threads, per = 8, 2000
    shared = reg.counter("shared_total")
    hist = reg.histogram("lat_seconds", buckets=(0.5,))
    gate = threading.Barrier(threads)

    def work(i):
        mine = reg.counter("per_thread_total", labels={"t": str(i)})
        gate.wait()
        for _ in range(per):
            shared.inc()
            mine.inc()
            hist.observe(0.25)

    ts = [threading.Thread(target=work, args=(i,)) for i in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert shared.value == threads * per
    assert hist.count == threads * per
    for i in range(threads):
        assert reg.counter("per_thread_total",
                           labels={"t": str(i)}).value == per


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

_HELP_RE = re.compile(r"^# HELP [a-zA-Z_:][a-zA-Z0-9_:]* .*$")
_TYPE_RE = re.compile(
    r"^# TYPE [a-zA-Z_:][a-zA-Z0-9_:]* (counter|gauge|histogram)$")
_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"                       # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\.)*"'   # first label
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\.)*")*\})?'
    r" (-?[0-9.eE+-]+|\+Inf|-Inf|NaN)$")               # value


def _grammar_check(text: str) -> None:
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# HELP"):
            assert _HELP_RE.match(line), line
        elif line.startswith("# TYPE"):
            assert _TYPE_RE.match(line), line
        else:
            assert _SAMPLE_RE.match(line), line


def test_prometheus_exposition_grammar():
    reg = MetricsRegistry()
    reg.counter("wmd_requests_total", "submitted requests",
                labels={"op": "plain"}).inc(5)
    reg.counter("wmd_requests_total", "submitted requests",
                labels={"op": "top_k"}).inc(2)
    reg.gauge("wmd_queue_depth", "queued requests").set(3)
    # label value that needs escaping
    reg.counter("wmd_errors_total",
                labels={"error": 'Runtime"Error"\nline\\x'}).inc()
    h = reg.histogram("wmd_batch_size", "batch occupancy",
                      buckets=DEFAULT_SIZE_BUCKETS)
    for v in (1, 3, 8, 300):
        h.observe(v)
    text = render_prometheus(reg)
    _grammar_check(text)
    # HELP/TYPE exactly once per metric name, before its samples
    assert text.count("# TYPE wmd_requests_total counter") == 1
    assert text.count("# HELP wmd_requests_total") == 1
    # histogram: cumulative buckets, +Inf == _count, _sum present
    lines = text.splitlines()
    buckets = [ln for ln in lines if ln.startswith("wmd_batch_size_bucket")]
    counts = [float(ln.rsplit(" ", 1)[1]) for ln in buckets]
    assert counts == sorted(counts)                    # cumulative
    assert buckets[-1].startswith('wmd_batch_size_bucket{le="+Inf"}')
    assert counts[-1] == 4.0
    assert any(ln == "wmd_batch_size_count 4" for ln in lines)
    assert any(ln.startswith("wmd_batch_size_sum") for ln in lines)


def test_metrics_server_scrape():
    reg = MetricsRegistry()
    reg.counter("up_total").inc()
    with MetricsServer(reg, port=0, host="127.0.0.1") as srv:
        url = f"http://127.0.0.1:{srv.port}"
        with urllib.request.urlopen(url + "/metrics", timeout=10) as r:
            assert r.status == 200
            assert "text/plain" in r.headers["Content-Type"]
            body = r.read().decode()
        _grammar_check(body)
        assert "up_total 1" in body
        with urllib.request.urlopen(url + "/healthz", timeout=10) as r:
            assert r.status == 200


# ---------------------------------------------------------------------------
# tracer: span-tree lifecycle
# ---------------------------------------------------------------------------


def test_tracer_basic_tree_and_chrome_export(tmp_path):
    tr = Tracer()
    tr.begin_request(1, t0=10.0, op="plain")
    tr.add_span(1, "queue", 10.0, 10.5)
    tr.add_span(1, "dispatch", 10.5, 11.0, batch=4)
    tr.end_request(1, t1=11.0, status="ok")
    tr.event("breaker.transition", kind="plain", frm="closed", to="open")
    assert tr.open_count == 0
    trees, events = tr.snapshot()
    assert len(trees) == 1 and trees[0]["status"] == "ok"
    assert [s["name"] for s in trees[0]["spans"]] == ["queue", "dispatch"]
    assert events[0]["event"] == "breaker.transition"
    doc = tr.chrome_trace()
    json.dumps(doc)                                    # strictly JSON-able
    assert doc["traceEvents"], "empty chrome trace"
    phs = {e["ph"] for e in doc["traceEvents"]}
    assert phs <= {"X", "i", "M"}
    root = [e for e in doc["traceEvents"] if e["name"] == "request[ok]"]
    assert len(root) == 1 and root[0]["dur"] == pytest.approx(1e6)
    out = tmp_path / "trace.json"
    tr.export_chrome(str(out))
    assert json.load(open(out))["traceEvents"]


def test_tracer_reused_seq_closes_orphan_and_anon_quarantine():
    tr = Tracer()
    tr.begin_request(7)
    tr.begin_request(7)                 # reuse before close
    tr.end_request(7, status="ok")
    tr.closed_request(status="quarantined", op="plain")
    trees, _ = tr.snapshot()
    statuses = sorted(t["status"] for t in trees)
    assert statuses == ["ok", "orphaned", "quarantined"]
    assert tr.open_count == 0


def test_jsonl_exporter_round_trip(tmp_path):
    tr = Tracer()
    path = tmp_path / "events.jsonl"
    exp = JsonlExporter(tr, str(path), interval_s=0.05)
    tr.event("wal.append.synced", path="/x", bytes=12)
    tr.event("brownout.enter", queue_depth=9)
    exp.close()                         # final flush
    lines = [json.loads(ln) for ln in open(path) if ln.strip()]
    assert [ev["event"] for ev in lines] == ["wal.append.synced",
                                            "brownout.enter"]
    assert exp.written == 2
    assert tr.drain_events() == []      # drained by the exporter


# ---------------------------------------------------------------------------
# event log: breaker / brownout / degraded / watchdog / WAL / compaction
# ---------------------------------------------------------------------------


def test_guard_events_breaker_degraded_and_metrics():
    import test_resilience as tres
    tr = Tracer()
    reg = MetricsRegistry()
    svc = tres.FlakyService(fail=50)    # exact tier always fails
    pol = ResiliencePolicy(max_retries=1, breaker_failures=2,
                           breaker_cooldown_s=30.0, backoff_base_s=0.0,
                           backoff_max_s=0.0)
    guard = EngineGuard(svc, pol, clock=tres.FakeClock(),
                        sleep=lambda s: None, tracer=tr, metrics=reg)
    res = guard.dispatch("plain", [np.ones(6, np.float32)])
    assert isinstance(res, DegradedResult)
    kinds = [e["event"] for e in tr.snapshot()[1]]
    assert "dispatch.failure" in kinds
    assert "breaker.transition" in kinds
    assert "degraded" in kinds
    trans = [e for e in tr.snapshot()[1] if e["event"] == "breaker.transition"]
    assert all(e["frm"] == "closed" and e["to"] == "open" for e in trans)
    assert reg.counter("wmd_breaker_transitions_total").value >= 1
    assert reg.counter("wmd_guard_degraded_total").value == 1
    assert reg.gauge("wmd_breaker_open_rungs").value >= 1


def test_guard_events_brownout_enter_exit():
    import test_resilience as tres
    tr = Tracer()
    clk = tres.FakeClock()
    pol = ResiliencePolicy(brownout_queue_hi=4, brownout_queue_lo=1)
    guard = EngineGuard(tres.FlakyService(fail=0), pol, clock=clk,
                        sleep=lambda s: None, tracer=tr)
    res = guard.dispatch("plain", [np.ones(6, np.float32)], queue_depth=10)
    assert isinstance(res, DegradedResult) and res.reason == "brownout"
    clk.advance(60.0)                   # past the exit dwell
    guard.dispatch("plain", [np.ones(6, np.float32)], queue_depth=0)
    kinds = [e["event"] for e in tr.snapshot()[1]]
    assert "brownout.enter" in kinds and "brownout.exit" in kinds
    assert kinds.index("brownout.enter") < kinds.index("brownout.exit")


def test_watchdog_strike_event():
    from repro.distributed.fault_tolerance import (FaultPolicy,
                                                   ServingWatchdog)
    import test_resilience as tres
    tr = Tracer()
    clk = tres.FakeClock()
    wd = ServingWatchdog(FaultPolicy(straggler_factor=2.0,
                                     straggler_strikes=1, timeout_s=5.0),
                         min_samples=1, clock=clk, tracer=tr)
    for _ in range(4):
        wd.beat("plain", 0.01, True)
    wd.beat("plain", 1.0, True)         # 100x the median: strike
    kinds = [e["event"] for e in tr.snapshot()[1]]
    assert "watchdog.strike" in kinds
    # stalled detection also lands in the log
    clk.advance(10.0)
    assert wd.check() == ["plain"]
    assert "watchdog.stalled" in [e["event"] for e in tr.snapshot()[1]]


def test_wal_and_compaction_boundary_events(tmp_path):
    from repro.data.live_corpus import LiveCorpus
    tr = Tracer()
    live = LiveCorpus(str(tmp_path / "live"), 32, tracer=tr)
    live.add_docs([0, 1], [[(2, 0.5), (3, 0.5)], [(4, 1.0)]])
    kinds = [e["event"] for e in tr.snapshot()[1]]
    for k in ("wal.append.pre", "wal.append.torn", "wal.append.synced"):
        assert k in kinds, k
    live.compact()
    kinds = [e["event"] for e in tr.snapshot()[1]]
    for k in ("compact.begin", "compact.built", "compact.snapshot.tmp",
              "compact.renamed", "compact.done"):
        assert k in kinds, k
    # the rotated-in WAL (fresh writer post-compaction) is traced too
    n_synced = kinds.count("wal.append.synced")
    live.add_docs([2], [[(5, 1.0)]])
    kinds = [e["event"] for e in tr.snapshot()[1]]
    assert kinds.count("wal.append.synced") == n_synced + 1


# ---------------------------------------------------------------------------
# chaos: every submitted request closes exactly one span tree
# ---------------------------------------------------------------------------


def test_chaos_every_request_closes_exactly_one_tree():
    import test_resilience as tres
    from repro.serving.faultinject import FaultSchedule, FaultyEngine
    svc = tres._service()
    qs = tres._queries(40, seed=4)
    bad = np.full(tres.VOCAB, np.nan, np.float32)      # quarantine fodder
    tr = Tracer()
    eng = FaultyEngine(svc, FaultSchedule(seed=11, p_error=0.2,
                                          p_latency=0.1, p_corrupt=0.1,
                                          latency_s=0.005))
    co = QueryCoalescer(eng, window_ms=1.0, max_batch=4,
                        resilience=tres.CHAOS_POLICY, tracer=tr)
    try:
        futs = [co.submit(q) for q in qs]
        for _ in range(3):
            with pytest.raises(Exception):
                co.submit(bad)                          # quarantined
        co.drain(timeout=120.0)
    finally:
        co.shutdown(drain=True, timeout=120.0)
    st = co.stats()
    assert st.submitted == len(qs) and st.quarantined == 3
    assert all(f.done() for f in futs)
    # exactly one closed tree per submitted OR quarantined request
    assert tr.open_count == 0, "span trees leaked open"
    trees, events = tr.snapshot()
    assert len(trees) == st.submitted + st.quarantined
    by_status: dict = {}
    for t in trees:
        by_status[t["status"]] = by_status.get(t["status"], 0) + 1
    assert by_status.get("quarantined", 0) == st.quarantined
    assert by_status.get("degraded", 0) == st.degraded
    assert by_status.get("failed", 0) == st.failed
    assert by_status.get("cancelled", 0) == st.cancelled
    ok = by_status.get("ok", 0)
    assert ok == st.completed - st.degraded
    seqs = [t["seq"] for t in trees]
    assert len(seqs) == len(set(seqs)), "a request closed twice"
    # completed requests carry full phase attribution: queue + dispatch
    for t in trees:
        if t["status"] in ("ok", "degraded"):
            names = [s["name"] for s in t["spans"]]
            assert "queue" in names and "dispatch" in names
            assert t["t1"] >= t["t0"]
            for s in t["spans"]:
                assert s["t1"] >= s["t0"] >= t["t0"]
    # the injected faults left their marks in the event log
    kinds = {e["event"] for e in events}
    assert "dispatch.failure" in kinds
    # Perfetto-loadable end product of the chaos run
    doc = tr.chrome_trace()
    json.dumps(doc)
    assert len([e for e in doc["traceEvents"]
                if e["ph"] == "X" and e["name"].startswith("request[")]) \
        == len(trees)


def test_cancelled_and_shutdown_requests_close_trees():
    import test_resilience as tres
    tr = Tracer()
    svc = tres.FlakyService()
    co = QueryCoalescer(svc, window_ms=10_000.0, max_batch=64, tracer=tr)
    futs = [co.submit(np.ones(6, np.float32)) for _ in range(4)]
    futs[0].cancel()                    # cancelled while queued
    co.shutdown(drain=False)            # rest fail with CoalescerClosedError
    st = co.stats()
    assert st.cancelled == 1 and st.failed == 3
    assert tr.open_count == 0
    trees, _ = tr.snapshot()
    statuses = sorted(t["status"] for t in trees)
    assert statuses == ["cancelled", "failed", "failed", "failed"]


# ---------------------------------------------------------------------------
# bitwise neutrality: obs on == obs off on the golden routes
# ---------------------------------------------------------------------------


def test_obs_on_bitwise_identical_to_golden_routes():
    import test_golden as tg
    golden = np.load(tg.GOLDEN)
    vecs, ell, rs = tg._corpus()
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService
    cfg = WMDConfig(name="golden", vocab_size=vecs.shape[0], embed_dim=8,
                    num_docs=ell.num_docs, nnz_max=ell.nnz_max,
                    v_r=tg.V_R_BUCKET, lamb=tg.LAMB, max_iter=tg.MAX_ITER)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=vecs, ell=ell,
                     cache_capacity=64, prune_chunk=8, bound_docs_chunk=None)
    assert svc.metrics is not None      # registry auto-attached
    # direct route, obs-on service
    np.testing.assert_array_equal(svc.query_batch(rs),
                                  golden["service_stripes"])
    idx_p, d_p = svc.top_k_batch(rs, tg.TOP_K, prune=True)
    np.testing.assert_array_equal(idx_p, golden["pruned_topk_idx"])
    np.testing.assert_array_equal(d_p, golden["pruned_topk_dist"])
    # traced + coalesced route: same bits as the direct golden route
    tr = Tracer()
    co = QueryCoalescer(svc, window_ms=1.0, max_batch=len(rs), tracer=tr)
    try:
        futs = [co.submit(r) for r in rs]
        out = np.stack([f.result(timeout=60.0) for f in futs])
    finally:
        co.shutdown(drain=True)
    np.testing.assert_array_equal(out, golden["service_stripes"])
    assert tr.open_count == 0 and len(tr.snapshot()[0]) == len(rs)
    # the mirrored K-cache counters saw the traffic without touching it
    assert svc.metrics.counter("wmd_kcache_lookups_total").value > 0


def test_null_tracer_is_inert_shared_default():
    import test_resilience as tres
    co = QueryCoalescer(tres.FlakyService(), window_ms=1.0, max_batch=4)
    assert co._tracer is NULL_TRACER and not NULL_TRACER.enabled
    try:
        assert co.submit(np.ones(6, np.float32)).result(timeout=30.0) \
            .shape == (6,)
    finally:
        co.shutdown(drain=True)
    # private registry by default: two coalescers never sum counters
    co2 = QueryCoalescer(tres.FlakyService(), window_ms=1.0, max_batch=4)
    co2.shutdown(drain=True)
    assert co.metrics is not co2.metrics


# ---------------------------------------------------------------------------
# last_batch_stats: the `{}`-clearing fix
# ---------------------------------------------------------------------------


def test_last_batch_stats_sequential_and_legacy_routes_report_time():
    import test_golden as tg
    vecs, ell, rs = tg._corpus()
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService
    cfg = WMDConfig(name="lbs", vocab_size=vecs.shape[0], embed_dim=8,
                    num_docs=ell.num_docs, nnz_max=ell.nnz_max,
                    v_r=tg.V_R_BUCKET, lamb=tg.LAMB, max_iter=tg.MAX_ITER)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=vecs, ell=ell,
                     cache_capacity=0)          # no cache: fast-path routes
    svc.query_batch([rs[0]])                    # singleton -> sequential
    st = svc.last_batch_stats
    assert st["route"] == "sequential"
    assert st["phases_separable"] is False and st["solve_s"] > 0.0
    svc.query_batch(rs)                         # Q>1 -> legacy fused
    st = svc.last_batch_stats
    assert st["route"] == "legacy_fused"
    assert st["phases_separable"] is False and st["solve_s"] > 0.0
