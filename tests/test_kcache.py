"""Cross-query K/KM row cache: exactness under hit/miss/evict sequences.

The cache's contract (core.kcache) is *bitwise* exactness: stripes assembled
from resident rows equal the recompute-from-scratch transient path bit for
bit, for any interleaving of hits, misses, evictions, capacity overflows and
lambda invalidations -- and therefore solver output is identical with the
cache on or off, for every impl. A seeded random-stream test always runs;
a hypothesis property test (optional dev dep) drives broader sequences.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import KCache
from repro.data import make_corpus, zipf_query_stream

V, W, LAMB = 192, 16, 1.0


@pytest.fixture(scope="module")
def vecs():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.normal(size=(V, W)).astype(np.float32))


def _batch(rng, q, v_r, vocab=V):
    """Random (sel_b, mask_b) with per-query padding like pad_query_batch."""
    sel = np.zeros((q, v_r), np.int32)
    mask = np.zeros((q, v_r), np.float32)
    for i in range(q):
        n = int(rng.integers(1, v_r + 1))
        sel[i, :n] = rng.choice(vocab, n, replace=False)
        mask[i, :n] = 1.0
    return sel, mask


def _assert_stripes_equal(got, want, ctx=""):
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]),
                                  err_msg=f"K {ctx}")
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]),
                                  err_msg=f"KM {ctx}")


def test_cached_stripes_bitwise_equal_recompute_oracle(vecs):
    """Random stream with evictions: every call's assembled stripes are
    bitwise equal to the transient recompute-from-scratch oracle."""
    rng = np.random.default_rng(1)
    kc = KCache(24, vecs, LAMB, rows_bucket=8)        # small: forces evicts
    oracle = KCache(0, vecs, LAMB, rows_bucket=8)     # capacity 0 = always
    for step in range(12):                            # recompute
        sel, mask = _batch(rng, q=int(rng.integers(1, 5)), v_r=6)
        got = kc.stripes_for_batch(sel, mask)
        want = oracle.stripes_for_batch(sel, mask)
        _assert_stripes_equal(got, want, ctx=f"step {step}")
    assert kc.stats.evictions > 0                     # pressure engaged
    assert kc.stats.hit_rows > 0
    assert kc.resident <= kc.capacity


def test_resident_rows_bitwise_equal_fresh_rows(vecs):
    """Rows sitting in the buffer equal a from-scratch recompute of the same
    word id, bit for bit (the row value is independent of which other ids
    missed alongside it)."""
    rng = np.random.default_rng(2)
    kc = KCache(32, vecs, LAMB, rows_bucket=8)
    oracle = KCache(0, vecs, LAMB, rows_bucket=8)
    for _ in range(4):
        sel, mask = _batch(rng, q=3, v_r=6)
        kc.stripes_for_batch(sel, mask)
    for wid, slot in list(kc._slot_of.items())[:10]:
        sel1 = np.full((1, 1), wid, np.int32)
        k_o, km_o, _ = oracle.stripes_for_batch(sel1, np.ones((1, 1),
                                                             np.float32))
        np.testing.assert_array_equal(np.asarray(kc._k_buf[:, slot]),
                                      np.asarray(k_o[:, 0, 0]), err_msg=str(wid))
        np.testing.assert_array_equal(np.asarray(kc._km_buf[:, slot]),
                                      np.asarray(km_o[:, 0, 0]))


def test_eviction_pressure_capacity_below_unique(vecs):
    """capacity < unique words in the stream: the LRU churns constantly yet
    every assembly stays exact, and the batch's own rows are never evicted
    mid-batch (capacity >= one batch's unique ids is the only requirement)."""
    rng = np.random.default_rng(3)
    kc = KCache(10, vecs, LAMB, rows_bucket=4)
    oracle = KCache(0, vecs, LAMB, rows_bucket=4)
    seen = set()
    for step in range(15):
        sel, mask = _batch(rng, q=2, v_r=5)
        seen.update(np.unique(sel).tolist())
        got = kc.stripes_for_batch(sel, mask)
        want = oracle.stripes_for_batch(sel, mask)
        _assert_stripes_equal(got, want, ctx=f"step {step}")
    assert len(seen) > kc.capacity                    # the premise
    assert kc.stats.evictions > 0
    assert kc.resident <= kc.capacity


def test_capacity_overflow_bypasses_store_exactly(vecs):
    """A batch with more unique ids than capacity takes the transient path
    (info.cached False), still bitwise exact, without corrupting the store."""
    rng = np.random.default_rng(4)
    kc = KCache(8, vecs, LAMB, rows_bucket=4)
    sel_small, mask_small = _batch(rng, q=1, v_r=5)
    kc.stripes_for_batch(sel_small, mask_small)
    resident_before = dict(kc._slot_of)
    sel_big = rng.choice(V, (2, 8), replace=False).astype(np.int32)
    mask_big = np.ones((2, 8), np.float32)
    got = kc.stripes_for_batch(sel_big, mask_big)
    assert got[2]["cached"] is False
    oracle = KCache(0, vecs, LAMB, rows_bucket=4)
    want = oracle.stripes_for_batch(sel_big, mask_big)
    _assert_stripes_equal(got, want)
    assert kc._slot_of == resident_before             # store untouched


def test_lamb_invalidation(vecs):
    """ensure_lamb drops the store on a lambda change and re-keys: rows under
    the new lambda equal a fresh cache's rows."""
    rng = np.random.default_rng(5)
    kc = KCache(32, vecs, LAMB, rows_bucket=8)
    sel, mask = _batch(rng, q=2, v_r=6)
    kc.stripes_for_batch(sel, mask)
    assert kc.resident > 0
    kc.ensure_lamb(LAMB)                              # no-op at same lambda
    assert kc.stats.invalidations == 0
    kc.ensure_lamb(2.5)
    assert kc.stats.invalidations == 1 and kc.resident == 0
    got = kc.stripes_for_batch(sel, mask)
    fresh = KCache(32, vecs, 2.5, rows_bucket=8)
    want = fresh.stripes_for_batch(sel, mask)
    _assert_stripes_equal(got, want)


def test_failed_miss_compute_does_not_poison_map(vecs, monkeypatch):
    """If the miss compute/scatter raises, no id may be left mapped as
    resident (unsubstantiated residency would serve zero/stale rows later);
    the allocated slots return to the free list and the next call is exact."""
    from repro.core import kcache as kc_mod
    rng = np.random.default_rng(6)
    kc = KCache(32, vecs, LAMB, rows_bucket=8)
    sel0, mask0 = _batch(rng, q=2, v_r=6)
    kc.stripes_for_batch(sel0, mask0)
    resident_before = dict(kc._slot_of)
    free_before = len(kc._free)
    orig = kc_mod._scatter_rows
    monkeypatch.setattr(kc_mod, "_scatter_rows",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("injected")))
    sel1, mask1 = _batch(rng, q=2, v_r=6)
    with pytest.raises(RuntimeError, match="injected"):
        kc.stripes_for_batch(sel1, mask1)
    # no new id became resident, and the slots went back to the free list
    assert set(kc._slot_of) <= set(resident_before)
    assert len(kc._free) >= free_before
    monkeypatch.setattr(kc_mod, "_scatter_rows", orig)
    got = kc.stripes_for_batch(sel1, mask1)
    want = KCache(0, vecs, LAMB, rows_bucket=8).stripes_for_batch(sel1, mask1)
    _assert_stripes_equal(got, want)


# ---------------------------------------------------------------------------
# hypothesis property test (optional dev dep, mirrors tests/test_properties)
# ---------------------------------------------------------------------------

def test_random_sequences_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    rng0 = np.random.default_rng(7)
    vecs_h = jnp.asarray(rng0.normal(size=(64, 8)).astype(np.float32))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 30), st.integers(0, 10_000),
           st.integers(1, 5), st.integers(2, 7))
    def prop(capacity, seed, n_batches, v_r):
        rng = np.random.default_rng(seed)
        kc = KCache(capacity, vecs_h, LAMB, rows_bucket=4)
        oracle = KCache(0, vecs_h, LAMB, rows_bucket=4)
        for _ in range(n_batches):
            sel, mask = _batch(rng, q=int(rng.integers(1, 4)), v_r=v_r,
                               vocab=64)
            got = kc.stripes_for_batch(sel, mask)
            want = oracle.stripes_for_batch(sel, mask)
            _assert_stripes_equal(got, want)
            assert kc.resident <= kc.capacity

    prop()


# ---------------------------------------------------------------------------
# Service-level: cache on/off bitwise through the full solver, all impls
# ---------------------------------------------------------------------------

def _service(**kw):
    from repro.configs import sinkhorn_wmd as wmd_cfg
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = wmd_cfg.smoke_config()
    data = make_corpus(vocab_size=cfg.vocab_size, embed_dim=cfg.embed_dim,
                       num_docs=cfg.num_docs, num_queries=5,
                       query_words=cfg.v_r - 2, seed=11)
    return WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                      **kw), data


@pytest.mark.parametrize("impl", ["fused", "unfused",
                                  pytest.param("kernel",
                                               marks=pytest.mark.kernel)])
def test_service_cache_on_off_bitwise(impl):
    """query_batch with the cache enabled is bitwise identical to the
    cache-off path for every impl, including after evictions (capacity is
    tiny) and repeat batches (hits)."""
    svc, data = _service(cache_capacity=24, cache_rows_bucket=8)
    for queries in (data.queries[:3], data.queries[1:5], data.queries[:3]):
        on = svc.query_batch(queries, impl=impl)
        off = svc.query_batch(queries, impl=impl, use_cache=False)
        np.testing.assert_array_equal(on, off)
    assert svc.cache_stats.hit_rows > 0


def test_service_cache_matches_sequential_and_stats():
    """Cached batched results match the sequential oracle numerically, and
    the service exposes the phase split + hit-rate stats the bench records."""
    svc, data = _service(cache_capacity=64, cache_rows_bucket=8)
    batch = svc.query_batch(data.queries)
    seq = svc.query_batch_sequential(data.queries)
    err = np.abs(batch - seq).max() / np.abs(seq).max()
    assert err < 1e-4, err
    again = svc.query_batch(data.queries)             # all-hit repeat
    np.testing.assert_array_equal(batch, again)
    st = svc.last_batch_stats
    assert st["hit_rate"] == 1.0 and st["cached"] is True
    assert st["precompute_s"] > 0 and st["solve_s"] > 0
    assert svc.cache_stats.lookups >= 2


def test_service_lamb_change_invalidates_cache():
    """Swapping cfg.lamb between calls re-keys the store (lambda-
    invalidation) and produces the new-lambda answer, bitwise equal to the
    cache-off path under the same service -- and the per-query engine
    (query / the sequential oracle) follows the new lambda too, so the
    service never serves mixed-lambda answers."""
    svc, data = _service(cache_capacity=64, cache_rows_bucket=8)
    before = svc.query_batch(data.queries[:2])
    assert svc.cache_resident > 0
    svc.cfg = dataclasses.replace(svc.cfg, lamb=2.0)
    on = svc.query_batch(data.queries[:2])
    assert svc.cache_stats.invalidations == 1
    assert svc._kcache.lamb == 2.0
    assert np.abs(on - before).max() > 0      # lambda actually changed
    off = svc.query_batch(data.queries[:2], use_cache=False)
    np.testing.assert_array_equal(on, off)
    seq = svc.query_batch_sequential(data.queries[:2])
    err = np.abs(on - seq).max() / np.abs(seq).max()
    assert err < 1e-4, err                    # per-query engine re-keyed too


def test_top_k_batch_matches_argsort_oracle():
    svc, data = _service(cache_capacity=64)
    d = svc.query_batch(data.queries)
    idx, dist = svc.top_k_batch(data.queries, k=4)
    ref = np.argsort(d, axis=-1)[:, :4]
    np.testing.assert_array_equal(idx, ref)
    np.testing.assert_array_equal(dist, np.take_along_axis(d, ref, axis=-1))
    i1, d1 = svc.top_k(data.queries[0], k=3)
    np.testing.assert_array_equal(i1, np.argsort(svc.query(
        data.queries[0]))[:3])
    assert d1.shape == (3,)
    # k > N degrades to a full sort, not an error
    i_all, _ = svc.top_k(data.queries[0], k=10_000)
    assert i_all.shape == (svc.ell.num_docs,)


def test_zipf_query_stream_seeded_and_skewed():
    """The stream is reproducible per seed and actually skewed: two seeds
    agree iff equal, and a steeper exponent concentrates ids."""
    s1 = zipf_query_stream(vocab_size=256, query_words=8, seed=3)
    s2 = zipf_query_stream(vocab_size=256, query_words=8, seed=3)
    a = [next(s1) for _ in range(4)]
    b = [next(s2) for _ in range(4)]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
        assert (x > 0).sum() == 8
    flat = zipf_query_stream(vocab_size=256, query_words=8, s=1.05, seed=0)
    steep = zipf_query_stream(vocab_size=256, query_words=8, s=2.0, seed=0)
    ids_of = lambda s: {int(i) for _ in range(12)        # noqa: E731
                        for i in np.nonzero(next(s))[0]}
    assert len(ids_of(steep)) < len(ids_of(flat))


def test_distributed_cache_stripes_match_single_chip():
    """Cache-assembled stripes through build_wmd_batch_fn_stripes on a
    (2, 2) mesh == per-query single-chip solves, and cache on/off stays
    bitwise on the mesh (subprocess: needs a forced device count)."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import select_query, sinkhorn_wmd_sparse, ell_from_dense
from repro.configs.sinkhorn_wmd import WMDConfig
from repro.launch.mesh import make_mesh
from repro.serving import WMDService

mesh = make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(3)
V, w, N = 256, 32, 64
vecs = rng.normal(size=(V, w)).astype(np.float32)
c = np.zeros((V, N), np.float32)
for j in range(N):
    widx = rng.choice(V, rng.integers(3, 15), replace=False)
    c[widx, j] = rng.random(widx.size).astype(np.float32)
    c[:, j] /= c[:, j].sum()
ell = ell_from_dense(c)
queries = []
for vrn in (5, 9, 14):
    r = np.zeros(V, np.float32)
    idx = rng.choice(V, vrn, replace=False)
    r[idx] = rng.random(vrn).astype(np.float32); r /= r.sum()
    queries.append(r)
cfg = WMDConfig(name="t", vocab_size=V, embed_dim=w, num_docs=N,
                nnz_max=ell.nnz_max, v_r=16, lamb=1.0, max_iter=12)
svc = WMDService(mesh=mesh, cfg=cfg, vecs=vecs, ell=ell,
                 cache_capacity=48, cache_rows_bucket=8)
got = svc.query_batch(queries)
ref = np.stack([np.asarray(sinkhorn_wmd_sparse(
    s, r, jnp.asarray(ell.cols), jnp.asarray(ell.vals), vecs, 1.0, 12))
    for s, r in [select_query(q) for q in queries]])
err = np.abs(got - ref).max() / np.abs(ref).max()
assert err < 1e-4, err
again = svc.query_batch(queries)          # warm: hits
off = svc.query_batch(queries, use_cache=False)
assert np.array_equal(got, again) and np.array_equal(got, off)
assert svc.cache_stats.hit_rows > 0
print("DIST_KCACHE_OK", err)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(repo, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "DIST_KCACHE_OK" in out.stdout
